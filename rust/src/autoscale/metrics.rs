//! The metrics pipeline: a metrics-server analogue.
//!
//! Each kubelet samples per-pod usage while reconciling its node and
//! publishes `PodMetrics` / `NodeMetrics` objects (group
//! `metrics.k8s.io/v1beta1`) through the ordinary API — the same objects
//! `kubectl top nodes|pods` renders and the HPA consumes. Samples also
//! land in the shared [`crate::cluster::Metrics`] registry as gauges so
//! `hpcorc metrics` shows live cluster usage without an API round-trip.
//!
//! # Usage model
//!
//! The container runtime is simulated, so "usage" is a synthetic but
//! *controllable* signal, resolved per running pod in priority order:
//!
//! 1. the live-patchable `autoscale.hpcorc.io/cpu-milli` **annotation**
//!    (how load generators and tests modulate load on running pods);
//! 2. the `CPU_LOAD_MILLI` container **env var** (how a Deployment
//!    template declares the steady-state load of new pods);
//! 3. half the pod's CPU request (a half-busy service — stable under the
//!    default 80% HPA target, so un-instrumented workloads never
//!    self-oscillate).
//!
//! Pods that are not `Running` report nothing. Memory usage is the pod's
//! request while running (fully resident). Writes are suppressed when the
//! sampled values did not change, so a quiet cluster generates no watch
//! traffic from its metrics pipeline.

use crate::cluster::{Metrics, Resources};
use crate::encoding::Value;
use crate::kube::{ApiClient, Informer, KubeObject, PodPhase, PodView};
use crate::util::Result;

/// The apiVersion the metrics kinds are served under.
pub const METRICS_API_VERSION: &str = "metrics.k8s.io/v1beta1";

pub const KIND_NODEMETRICS: &str = "NodeMetrics";
pub const KIND_PODMETRICS: &str = "PodMetrics";

/// Live-patchable per-pod CPU usage override (millicores).
pub const CPU_USAGE_ANNOTATION: &str = "autoscale.hpcorc.io/cpu-milli";
/// Template-declared per-pod CPU usage (millicores), read from the
/// container env.
pub const CPU_LOAD_ENV: &str = "CPU_LOAD_MILLI";

/// Synthetic CPU usage of one pod in millicores (see the module docs for
/// the resolution order). Only meaningful for `Running` pods — callers
/// skip the rest.
pub fn pod_cpu_usage_milli(obj: &KubeObject, view: &PodView) -> u64 {
    if let Some(v) = obj
        .meta
        .annotations
        .iter()
        .find(|(k, _)| k == CPU_USAGE_ANNOTATION)
        .and_then(|(_, v)| v.parse::<u64>().ok())
    {
        return v;
    }
    if let Some(v) = view
        .env
        .iter()
        .find(|(k, _)| k == CPU_LOAD_ENV)
        .and_then(|(_, v)| v.parse::<u64>().ok())
    {
        return v;
    }
    view.requests.cpu_milli / 2
}

/// Typed view over a PodMetrics object.
#[derive(Debug, Clone, PartialEq)]
pub struct PodMetricsView {
    pub name: String,
    pub node_name: String,
    pub cpu_milli: u64,
    pub mem_bytes: u64,
}

impl PodMetricsView {
    pub fn from_object(o: &KubeObject) -> Result<PodMetricsView> {
        if o.kind != KIND_PODMETRICS {
            return Err(crate::util::Error::parse(format!(
                "expected PodMetrics, got {}",
                o.kind
            )));
        }
        Ok(PodMetricsView {
            name: o.meta.name.clone(),
            node_name: o.spec.opt_str("nodeName").unwrap_or("").to_string(),
            cpu_milli: o.spec.path(&["usage", "cpu"]).and_then(Value::as_int).unwrap_or(0)
                as u64,
            mem_bytes: o.spec.path(&["usage", "memory"]).and_then(Value::as_int).unwrap_or(0)
                as u64,
        })
    }
}

impl crate::kube::ResourceView for PodMetricsView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_PODMETRICS]
    }
    fn from_object(obj: &KubeObject) -> Result<PodMetricsView> {
        PodMetricsView::from_object(obj)
    }
}

/// Typed view over a NodeMetrics object.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeMetricsView {
    pub name: String,
    pub usage_cpu_milli: u64,
    pub usage_mem_bytes: u64,
    pub capacity: Resources,
}

impl NodeMetricsView {
    pub fn from_object(o: &KubeObject) -> Result<NodeMetricsView> {
        if o.kind != KIND_NODEMETRICS {
            return Err(crate::util::Error::parse(format!(
                "expected NodeMetrics, got {}",
                o.kind
            )));
        }
        Ok(NodeMetricsView {
            name: o.meta.name.clone(),
            usage_cpu_milli: o.spec.path(&["usage", "cpu"]).and_then(Value::as_int).unwrap_or(0)
                as u64,
            usage_mem_bytes: o
                .spec
                .path(&["usage", "memory"])
                .and_then(Value::as_int)
                .unwrap_or(0) as u64,
            capacity: Resources {
                cpu_milli: o
                    .spec
                    .path(&["capacity", "cpu"])
                    .and_then(Value::as_int)
                    .unwrap_or(0) as u64,
                mem_bytes: o
                    .spec
                    .path(&["capacity", "memory"])
                    .and_then(Value::as_int)
                    .unwrap_or(0) as u64,
                gpus: 0,
            },
        })
    }
}

impl crate::kube::ResourceView for NodeMetricsView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_NODEMETRICS]
    }
    fn from_object(obj: &KubeObject) -> Result<NodeMetricsView> {
        NodeMetricsView::from_object(obj)
    }
}

fn usage_value(cpu_milli: u64, mem_bytes: u64) -> Value {
    Value::map().with("cpu", cpu_milli).with("memory", mem_bytes)
}

fn pod_metrics_object(pod: &str, node: &str, cpu_milli: u64, mem_bytes: u64) -> KubeObject {
    let spec = Value::map()
        .with("nodeName", node)
        .with("usage", usage_value(cpu_milli, mem_bytes));
    let mut o = KubeObject::new(KIND_PODMETRICS, pod, spec);
    o.api_version = METRICS_API_VERSION.into();
    // Owned by the pod it samples: cascade delete collects the sample
    // when the pod goes away (the reap below covers rebinds).
    o.meta.owner = Some((crate::kube::KIND_POD.to_string(), pod.to_string()));
    o
}

fn node_metrics_object(
    node: &str,
    cpu_milli: u64,
    mem_bytes: u64,
    capacity: Resources,
) -> KubeObject {
    let spec = Value::map().with("usage", usage_value(cpu_milli, mem_bytes)).with(
        "capacity",
        Value::map()
            .with("cpu", capacity.cpu_milli)
            .with("memory", capacity.mem_bytes),
    );
    let mut o = KubeObject::new(KIND_NODEMETRICS, node, spec);
    o.api_version = METRICS_API_VERSION.into();
    // Owned by the Node object: when the cluster autoscaler drains a
    // pool node and deletes it, the cascade removes the sample too —
    // `kubectl top nodes` never shows ghosts of deprovisioned nodes.
    o.meta.owner = Some((crate::kube::KIND_NODE.to_string(), node.to_string()));
    o
}

/// Apply an object only when the cached copy's spec differs — metrics are
/// republished every kubelet sync, and an unchanged cluster must not
/// generate a write (and watch-event) storm. The comparison reads the
/// shared PodMetrics/NodeMetrics cache, so suppression costs no RPC.
fn apply_on_change(api: &dyn ApiClient, samples: &Informer, obj: KubeObject) {
    match samples.get(&obj.meta.name) {
        Some(existing) if existing.kind == obj.kind && existing.spec == obj.spec => {}
        _ => {
            let _ = api.apply(obj);
        }
    }
}

/// One kubelet's sampling pass: compute per-pod usage for `pods` (the
/// pods bound to `node`), publish `PodMetrics` for the running ones plus
/// this node's `NodeMetrics` aggregate, delete `PodMetrics` of pods that
/// stopped running here, and mirror the aggregate into `metrics` gauges.
/// `samples` is the shared PodMetrics informer — existing samples are
/// read from its cache (node-indexed), never listed.
///
/// Called from [`crate::kube::Kubelet::sync_once`]; also callable
/// directly for deterministic stepping in tests.
pub fn publish_node_sample(
    api: &dyn ApiClient,
    samples: &Informer,
    node: &str,
    capacity: Resources,
    pods: &[KubeObject],
    metrics: &Metrics,
) {
    samples.ensure_field_index("spec.nodeName");
    if let Err(e) = samples.sync() {
        // Stale suppression state only risks a redundant write or a
        // deferred reap — both converge next sync; keep publishing.
        crate::warn!("autoscale", "PodMetrics informer sync failed: {e}");
    }
    let mut node_cpu = 0u64;
    let mut node_mem = 0u64;
    let mut running: Vec<(String, u64, u64)> = Vec::new();
    for obj in pods {
        let Ok(view) = PodView::from_object(obj) else { continue };
        if view.phase != PodPhase::Running {
            continue;
        }
        let cpu = pod_cpu_usage_milli(obj, &view);
        let mem = view.requests.mem_bytes;
        node_cpu += cpu;
        node_mem += mem;
        running.push((view.name, cpu, mem));
    }
    // Reap metrics of pods that no longer run here (completed, deleted,
    // evicted, or rebound) so `kubectl top pods` never shows ghosts.
    for m in samples.list_by_field("spec.nodeName", node) {
        if m.kind == KIND_PODMETRICS && !running.iter().any(|(name, _, _)| name == &m.meta.name)
        {
            let _ = api.delete(KIND_PODMETRICS, &m.meta.name);
        }
    }
    for (name, cpu, mem) in &running {
        apply_on_change(api, samples, pod_metrics_object(name, node, *cpu, *mem));
    }
    apply_node_metrics_on_change(api, node, node_cpu, node_mem, capacity);
    metrics.set_gauge(&format!("autoscale.node.{node}.cpu_milli"), node_cpu as i64);
    metrics.set_gauge(&format!("autoscale.node.{node}.pods"), running.len() as i64);
}

/// NodeMetrics write suppression: one bounded `get` per sync (not a
/// list); the per-pod suppression above is fully cache-backed.
fn apply_node_metrics_on_change(
    api: &dyn ApiClient,
    node: &str,
    cpu: u64,
    mem: u64,
    capacity: Resources,
) {
    let obj = node_metrics_object(node, cpu, mem, capacity);
    match api.get(KIND_NODEMETRICS, node) {
        Ok(existing) if existing.spec == obj.spec => {}
        _ => {
            let _ = api.apply(obj);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::{ApiServer, KIND_POD};

    fn samples(api: &ApiServer) -> Informer {
        crate::kube::SharedInformerFactory::new(api.client(), Metrics::new())
            .informer(KIND_PODMETRICS)
    }

    fn running_pod(api: &ApiServer, name: &str, cpu_req: u64, env: &[(String, String)]) {
        let mut pod = PodView::build(name, "img.sif", Resources::new(cpu_req, 1 << 20, 0), env);
        pod.spec.insert("nodeName", "w1");
        api.create(pod).unwrap();
        api.update_status(KIND_POD, name, |o| {
            o.status.insert("phase", "Running");
        })
        .unwrap();
    }

    #[test]
    fn usage_resolution_order() {
        let mut pod = PodView::build(
            "p",
            "img.sif",
            Resources::new(1000, 1 << 20, 0),
            &[(CPU_LOAD_ENV.to_string(), "700".to_string())],
        );
        let view = PodView::from_object(&pod).unwrap();
        assert_eq!(pod_cpu_usage_milli(&pod, &view), 700, "env beats default");
        pod.meta.annotations.push((CPU_USAGE_ANNOTATION.to_string(), "250".to_string()));
        assert_eq!(pod_cpu_usage_milli(&pod, &view), 250, "annotation beats env");
        let plain = PodView::build("q", "img.sif", Resources::new(1000, 1 << 20, 0), &[]);
        let view = PodView::from_object(&plain).unwrap();
        assert_eq!(pod_cpu_usage_milli(&plain, &view), 500, "default: half the request");
    }

    #[test]
    fn publish_writes_pod_and_node_metrics() {
        let api = ApiServer::new(Metrics::new());
        let m = Metrics::new();
        let sm = samples(&api);
        running_pod(&api, "a", 1000, &[(CPU_LOAD_ENV.to_string(), "900".to_string())]);
        running_pod(&api, "b", 1000, &[]);
        let pods = api.list(KIND_POD, &[]);
        let cap = Resources::cores(8, 32 << 30);
        publish_node_sample(&api, &sm, "w1", cap, &pods, &m);

        let pm = PodMetricsView::from_object(&api.get(KIND_PODMETRICS, "a").unwrap()).unwrap();
        assert_eq!(pm.cpu_milli, 900);
        assert_eq!(pm.node_name, "w1");
        let nm =
            NodeMetricsView::from_object(&api.get(KIND_NODEMETRICS, "w1").unwrap()).unwrap();
        assert_eq!(nm.usage_cpu_milli, 900 + 500);
        assert_eq!(nm.capacity.cpu_milli, 8000);
        assert_eq!(m.gauge("autoscale.node.w1.pods").load(std::sync::atomic::Ordering::Relaxed), 2);

        // Unchanged resample writes nothing.
        let v = api.current_version();
        publish_node_sample(&api, &sm, "w1", cap, &api.list(KIND_POD, &[]), &m);
        assert_eq!(api.current_version(), v, "steady state is write-free");
    }

    /// Regression: without owner references, a drained pool node's
    /// NodeMetrics (and a deleted pod's PodMetrics) lived forever as
    /// `kubectl top` ghosts.
    #[test]
    fn metrics_objects_cascade_with_their_owners() {
        let api = ApiServer::new(Metrics::new());
        let m = Metrics::new();
        let cap = Resources::cores(8, 32 << 30);
        let sm = samples(&api);
        api.create(crate::kube::NodeView::build("w1", cap, &[])).unwrap();
        running_pod(&api, "a", 1000, &[]);
        publish_node_sample(&api, &sm, "w1", cap, &api.list(KIND_POD, &[]), &m);
        assert!(api.get(KIND_PODMETRICS, "a").is_ok());
        assert!(api.get(KIND_NODEMETRICS, "w1").is_ok());
        api.delete(KIND_POD, "a").unwrap();
        assert!(api.get(KIND_PODMETRICS, "a").is_err(), "pod cascade removes its sample");
        api.delete(crate::kube::KIND_NODE, "w1").unwrap();
        assert!(
            api.get(KIND_NODEMETRICS, "w1").is_err(),
            "node cascade removes its sample"
        );
    }

    #[test]
    fn stale_pod_metrics_reaped_and_usage_repatchable() {
        let api = ApiServer::new(Metrics::new());
        let m = Metrics::new();
        let sm = samples(&api);
        running_pod(&api, "a", 1000, &[]);
        let cap = Resources::cores(8, 32 << 30);
        publish_node_sample(&api, &sm, "w1", cap, &api.list(KIND_POD, &[]), &m);
        assert!(api.get(KIND_PODMETRICS, "a").is_ok());

        // Live annotation patch shifts the next sample.
        api.update_status(KIND_POD, "a", |o| {
            o.meta.annotations.push((CPU_USAGE_ANNOTATION.to_string(), "123".to_string()));
        })
        .unwrap();
        publish_node_sample(&api, &sm, "w1", cap, &api.list(KIND_POD, &[]), &m);
        let pm = PodMetricsView::from_object(&api.get(KIND_PODMETRICS, "a").unwrap()).unwrap();
        assert_eq!(pm.cpu_milli, 123);

        // Completion reaps the PodMetrics and zeroes the node aggregate.
        api.update_status(KIND_POD, "a", |o| {
            o.status.insert("phase", "Succeeded");
        })
        .unwrap();
        publish_node_sample(&api, &sm, "w1", cap, &api.list(KIND_POD, &[]), &m);
        assert!(api.get(KIND_PODMETRICS, "a").is_err(), "ghost metrics reaped");
        let nm =
            NodeMetricsView::from_object(&api.get(KIND_NODEMETRICS, "w1").unwrap()).unwrap();
        assert_eq!(nm.usage_cpu_milli, 0);
    }
}
