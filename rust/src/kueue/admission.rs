//! The admission cycle: suspend → reserve → admit → preempt, level-
//! triggered over the shared informer caches.
//!
//! Each cycle reads queues and workloads from the [`Informer`] caches —
//! zero list RPCs — and converges the system one step. The quota
//! [`Ledger`] is **incremental** (the ROADMAP's named scale step past
//! ~100k queued workloads): admitted charges are maintained by
//! charge/uncharge on watch deltas, idempotently keyed per member, with
//! a full rebuild only when a ClusterQueue *spec* changes or a workload
//! informer bumps its resync epoch (the 410-Gone path: events may have
//! been lost, so per-event arithmetic can no longer be trusted).
//! Workloads whose quota cannot be reserved are simply *left alone*
//! (their missing `Admitted` condition is the suspension — scheduler and
//! operator gate on it), so a crashed controller resumes from the
//! objects themselves.
//!
//! Gangs are atomic throughout: a multi-node WlmJob is one indivisible
//! demand, a pod group only becomes admissible once all declared members
//! exist, and the `Admitted` conditions of a gang's members are only ever
//! written after the *entire* gang's quota was reserved in the ledger.

use super::preemption::{evict_gang, select_victims, AdmittedGang};
use super::quota::{Fit, Ledger};
use super::types::{
    is_admitted, queue_name, set_condition, workload_demand, workload_priority,
    workload_terminal, ClusterQueueView, LocalQueueView, QueueOrdering, QueueResources,
    COND_ADMITTED, COND_EVICTED, COND_QUOTA_RESERVED, KIND_CLUSTERQUEUE, KIND_LOCALQUEUE,
    POD_GROUP_COUNT_ANNOTATION, POD_GROUP_LABEL, QUEUE_NAME_LABEL, SCHEDULING_GATE,
    WORKLOAD_KINDS,
};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::kube::{
    add_scheduling_gate, remove_scheduling_gate, scheduling_gates, ApiClient, EventRecorder,
    Informer, InformerEvent, KubeObject, SharedInformerFactory, EVENT_NORMAL, EVENT_WARNING,
    KIND_POD,
};
use crate::util::Result;
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Mutex;

/// Component name stamped on events and audit records this controller
/// writes.
const COMPONENT: &str = "kueue";

/// What one cycle did (workload-object granularity).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleReport {
    /// Workload objects newly admitted this cycle.
    pub admitted: usize,
    /// Workload objects evicted by preemption this cycle.
    pub preempted: usize,
    /// Workload objects still gated after this cycle.
    pub pending: usize,
}

/// A not-yet-admitted gang under consideration.
#[derive(Debug, Clone)]
struct PendingGang {
    members: Vec<(String, String)>,
    /// Per-member demand, aligned with `members` (the incremental
    /// ledger's charge granularity).
    member_demands: Vec<QueueResources>,
    /// Per-member `hpcorc.io/trace` annotation, aligned with `members` —
    /// each member's Admitted event carries its own originating trace.
    member_traces: Vec<Option<String>>,
    /// ClusterQueue charged on admission.
    cq: String,
    /// The raw queue-name label (LocalQueue counts key).
    label: String,
    demand: QueueResources,
    priority: i64,
    /// Min member uid: FIFO key (uids are assigned in creation order).
    uid: u64,
    /// Pod groups: all declared members present?
    complete: bool,
    /// Originating trace of the first member that carries one
    /// (`hpcorc.io/trace`): the admission write joins the create's tree.
    trace: Option<crate::obs::TraceContext>,
}

/// The incremental quota state carried between cycles: the live ledger
/// plus the per-member charge map that makes delta application
/// idempotent, and the triggers that demand a full rebuild.
struct LedgerState {
    ledger: Ledger,
    /// (kind, name) → (ClusterQueue charged, that member's demand).
    charged: BTreeMap<(String, String), (String, QueueResources)>,
    /// ClusterQueue name → spec tree at the last (re)build. Any change
    /// (add/remove/quota edit) invalidates per-event arithmetic.
    cq_specs: BTreeMap<String, Value>,
    /// Workload informer resync epochs at the last (re)build. A bump
    /// means events may have been lost (410-Gone recovery) — rebuild.
    epochs: Vec<u64>,
    inited: bool,
    rebuilds: u64,
}

/// The admission controller core. Reads from the shared informer caches;
/// carries the incremental [`LedgerState`] between cycles; cycles
/// themselves are serialized (see [`AdmissionCore::cycle`]).
pub struct AdmissionCore {
    metrics: Metrics,
    events: EventRecorder,
    cqs: Informer,
    lqs: Informer,
    /// One shared informer per [`WORKLOAD_KINDS`] entry, same order.
    workloads: Vec<Informer>,
    /// Merged delta stream from every workload informer — the
    /// incremental ledger's input.
    deltas: Mutex<Receiver<InformerEvent>>,
    state: Mutex<LedgerState>,
    /// Serializes cycles: the shared core is driven from one runner
    /// thread per watched kind, and two concurrent cycles could each
    /// admit a different gang against the same quota headroom. Under the
    /// lock, every cycle syncs *after* the previous cycle's admission
    /// writes landed.
    serial: Mutex<()>,
    /// (ClusterQueue, gang uid) pairs whose QuotaExhausted event was
    /// already emitted — the event is edge-triggered so a still-blocked
    /// head of queue keeps the "steady state writes nothing" property.
    blocked_noted: Mutex<std::collections::BTreeSet<(String, u64)>>,
}

impl AdmissionCore {
    pub fn new(informers: &SharedInformerFactory, metrics: Metrics) -> AdmissionCore {
        let (tx, rx) = channel();
        let mut workloads = Vec::with_capacity(WORKLOAD_KINDS.len());
        for kind in WORKLOAD_KINDS {
            let inf = informers.informer(kind);
            // Label-key-filtered: unlabelled pod churn (clusters that
            // never opted into queueing) is dropped inside the reflector
            // before any clone, preserving the "pay ~nothing per event"
            // property. Label *removal* still delivers that one
            // transition (the informer also matches the pre-event cached
            // labels), so `charge_entry` returns `None` for the stripped
            // object and `apply_delta` uncharges it immediately — no
            // rebuild needed to release the quota.
            inf.subscribe_with_label_key(tx.clone(), QUEUE_NAME_LABEL);
            workloads.push(inf);
        }
        AdmissionCore {
            events: EventRecorder::new(COMPONENT, metrics.clone()),
            metrics,
            cqs: informers.informer(KIND_CLUSTERQUEUE),
            lqs: informers.informer(KIND_LOCALQUEUE),
            workloads,
            deltas: Mutex::new(rx),
            state: Mutex::new(LedgerState {
                ledger: Ledger::default(),
                charged: BTreeMap::new(),
                cq_specs: BTreeMap::new(),
                epochs: Vec::new(),
                inited: false,
                rebuilds: 0,
            }),
            serial: Mutex::new(()),
            blocked_noted: Mutex::new(std::collections::BTreeSet::new()),
        }
    }

    /// How many times the incremental ledger was fully rebuilt (cold
    /// start, queue-spec change, or informer resync). Steady-state event
    /// processing must not move this — asserted by `tests/informer.rs`.
    pub fn ledger_rebuilds(&self) -> u64 {
        self.state.lock().unwrap().rebuilds
    }

    /// What the ledger should charge for `obj` right now: its stamped
    /// (or, for legacy objects, label-resolved) ClusterQueue and demand —
    /// `None` when the object holds no charge (unlabelled, suspended,
    /// terminal, undecodable, or unresolvable). The single predicate both
    /// the delta path and the rebuild path share, so they can never
    /// disagree.
    fn charge_entry(
        obj: &KubeObject,
        resolve: &dyn Fn(&str) -> Option<String>,
    ) -> Option<(String, QueueResources)> {
        let label = queue_name(obj)?;
        if !is_admitted(obj) || workload_terminal(obj) {
            return None;
        }
        // Admitted workloads charge the ClusterQueue stamped on them at
        // admission time — deleting or retargeting a LocalQueue must not
        // drop live charges (overcommit); the label fallback covers
        // objects admitted before stamping existed.
        let cq = obj
            .status
            .opt_str("clusterQueue")
            .map(String::from)
            .or_else(|| resolve(label))?;
        let demand = workload_demand(obj).ok()?;
        Some((cq, demand))
    }

    /// Idempotent charge/uncharge of one member against the incremental
    /// ledger (`entry` = what the charge should now be).
    fn apply_delta(
        st: &mut LedgerState,
        key: (String, String),
        entry: Option<(String, QueueResources)>,
    ) {
        match (st.charged.get(&key).cloned(), entry) {
            (None, None) => {}
            (None, Some((cq, d))) => {
                st.ledger.charge(&cq, &d);
                st.charged.insert(key, (cq, d));
            }
            (Some((cq, d)), None) => {
                st.ledger.uncharge(&cq, &d);
                st.charged.remove(&key);
            }
            (Some((ocq, od)), Some((ncq, nd))) => {
                if ocq != ncq || od != nd {
                    st.ledger.uncharge(&ocq, &od);
                    st.ledger.charge(&ncq, &nd);
                    st.charged.insert(key, (ncq, nd));
                }
            }
        }
    }

    /// Full rebuild from the caches — exactly what a fresh controller
    /// would compute, so resync recovery and cold start share one path.
    fn rebuild(
        &self,
        st: &mut LedgerState,
        cq_views: &[ClusterQueueView],
        resolve: &dyn Fn(&str) -> Option<String>,
    ) {
        st.ledger = Ledger::new(cq_views.to_vec());
        st.charged.clear();
        for inf in &self.workloads {
            for obj in inf.list_with_label_key(QUEUE_NAME_LABEL) {
                if let Some((cq, d)) = Self::charge_entry(&obj, resolve) {
                    st.ledger.charge(&cq, &d);
                    st.charged.insert((obj.kind.clone(), obj.meta.name.clone()), (cq, d));
                }
            }
        }
        st.rebuilds += 1;
        self.metrics.inc("kueue.ledger_rebuilds");
    }

    /// One full admission cycle. Public for deterministic stepping in
    /// tests and benches; the controller runtime calls it on every queue
    /// or workload event. Reads come from the shared caches and the
    /// ledger advances by watch deltas — steady state issues zero list
    /// RPCs.
    pub fn cycle(&self, api: &dyn ApiClient) -> Result<CycleReport> {
        let _one_at_a_time = self.serial.lock().unwrap();
        let t0 = std::time::Instant::now();
        // Every write this cycle makes is attributed to kueue in the API
        // server's audit trail (PR 8).
        let _actor = crate::obs::push_actor(COMPONENT);
        self.metrics.inc("kueue.cycles");

        // ---- refresh the caches -------------------------------------
        self.cqs.sync()?;
        self.lqs.sync()?;
        for inf in &self.workloads {
            inf.sync()?;
        }

        // ---- the queue topology (from cache) ------------------------
        // Views and the spec snapshot (the rebuild trigger) MUST come
        // from one atomic read: the factory pump thread syncs caches
        // concurrently, and taking them in two reads could pair stale
        // views with fresh specs — the rebuild would then bake the stale
        // quotas into the ledger while recording the new specs, so no
        // later cycle would ever notice.
        let (cqs, cq_specs): (Vec<ClusterQueueView>, BTreeMap<String, Value>) =
            self.cqs.read(|objs| {
                (
                    objs.values().filter_map(|o| ClusterQueueView::from_object(o).ok()).collect(),
                    objs.values().map(|o| (o.meta.name.clone(), o.spec.clone())).collect(),
                )
            });
        let lqs: Vec<LocalQueueView> = self.lqs.read(|objs| {
            objs.values().filter_map(|o| LocalQueueView::from_object(o).ok()).collect()
        });
        let resolve = |label: &str| -> Option<String> {
            lqs.iter()
                .find(|lq| lq.name == label)
                .map(|lq| lq.cluster_queue.clone())
                .or_else(|| {
                    cqs.iter().find(|cq| cq.name == label).map(|cq| cq.name.clone())
                })
                .filter(|cq| cqs.iter().any(|c| &c.name == cq))
        };

        // ---- incremental ledger maintenance -------------------------
        // Rebuild triggers: cold start, any ClusterQueue *spec* change
        // (status count writes don't count), or a workload informer
        // resync epoch bump (events may have been lost — the 410 path).
        let mut st = self.state.lock().unwrap();
        let epochs: Vec<u64> = self.workloads.iter().map(|i| i.epoch()).collect();
        let mut needs_rebuild = !st.inited || st.cq_specs != cq_specs || st.epochs != epochs;
        // Drain deltas either way (the channel must not grow unbounded);
        // apply them only while per-event arithmetic is trustworthy — a
        // rebuild re-derives everything from the cache anyway.
        {
            let rx = self.deltas.lock().unwrap();
            for ev in rx.try_iter() {
                match ev {
                    // A relist landed after the epoch snapshot above (the
                    // factory pump thread runs concurrently): events may
                    // have been lost, so the epoch comparison alone is
                    // not enough — the Resync itself forces the rebuild.
                    InformerEvent::Resync { .. } => needs_rebuild = true,
                    _ if needs_rebuild => {}
                    InformerEvent::Applied(o) => {
                        let key = (o.kind.clone(), o.meta.name.clone());
                        let entry = Self::charge_entry(&o, &resolve);
                        Self::apply_delta(&mut st, key, entry);
                    }
                    InformerEvent::Deleted(o) => {
                        Self::apply_delta(
                            &mut st,
                            (o.kind.clone(), o.meta.name.clone()),
                            None,
                        );
                    }
                }
            }
        }
        if needs_rebuild {
            self.rebuild(&mut st, &cqs, &resolve);
        }
        st.cq_specs = cq_specs;
        // Record the epoch baseline AFTER the drain: a relist that raced
        // the snapshot above was just handled through its Resync event; a
        // relist landing after this point delivers its Resync to the next
        // cycle's drain.
        st.epochs = self.workloads.iter().map(|i| i.epoch()).collect();
        st.inited = true;

        if cqs.is_empty() && lqs.is_empty() {
            // No queue topology: nothing can be admitted and no counts
            // can change — clusters that never opted into queueing pay
            // ~nothing per event.
            return Ok(CycleReport::default());
        }

        // ---- workloads (label-indexed cache scan) -------------------
        // Group by (queue label, pod group); solitary workloads are their
        // own group. Admitted and pending members of the same group
        // accumulate separately (keyed by the admitted flag): a
        // partially-admitted group (crash mid-write) thus splits — the
        // admitted members hold their ledger charges, the remainder forms
        // a pending gang — and re-running the cycle completes the
        // admission. The label-key index means the scan touches only
        // queue-labelled workloads, not the whole pod population.
        let mut gangs: BTreeMap<(bool, String, String), PendingGang> = BTreeMap::new();
        let mut declared_counts: BTreeMap<(String, String), usize> = BTreeMap::new();
        let mut group_sizes: BTreeMap<(String, String), usize> = BTreeMap::new();
        let mut poisoned: std::collections::BTreeSet<(String, String)> =
            std::collections::BTreeSet::new();
        for inf in &self.workloads {
            for obj in inf.list_with_label_key(QUEUE_NAME_LABEL) {
                let Some(label) = queue_name(&obj).map(String::from) else { continue };
                // Back-fill the scheduling gate on labelled pods created
                // without one. The ApiServer's mutating-admission hook
                // ([`super::types::admission_mutating_hook`]) gates them
                // at creation; this converges stragglers born before the
                // hook was registered (or through a hook-less server).
                if obj.kind == KIND_POD
                    && !is_admitted(&obj)
                    && !workload_terminal(&obj)
                    && !scheduling_gates(&obj).iter().any(|g| g == SCHEDULING_GATE)
                {
                    let _ = api.update_status(KIND_POD, &obj.meta.name, &|o| {
                        if !is_admitted(o) {
                            add_scheduling_gate(o, SCHEDULING_GATE);
                        }
                    });
                    self.metrics.inc("kueue.gates_backfilled");
                }
                // Admitted workloads charge the ClusterQueue stamped on
                // them at admission time — deleting or retargeting a
                // LocalQueue must not drop live charges (overcommit);
                // pending workloads resolve through the live topology.
                let stamped = obj.status.opt_str("clusterQueue").map(String::from);
                let resolved = if is_admitted(&obj) {
                    stamped.or_else(|| resolve(&label))
                } else {
                    resolve(&label)
                };
                let Some(cq) = resolved else {
                    self.metrics.inc("kueue.unresolved_queue");
                    continue; // stays suspended until its queue exists
                };
                let group = obj
                    .meta
                    .label(POD_GROUP_LABEL)
                    .map(String::from)
                    .unwrap_or_else(|| format!("__solo/{}/{}", obj.kind, obj.meta.name));
                let key = (label.clone(), group);
                *group_sizes.entry(key.clone()).or_insert(0) += 1;
                if let Some(count) = annotation(&obj, POD_GROUP_COUNT_ANNOTATION)
                    .and_then(|v| v.parse::<usize>().ok())
                {
                    let slot = declared_counts.entry(key.clone()).or_insert(0);
                    *slot = (*slot).max(count);
                }
                // Terminal members release their quota charge but still
                // count toward the declared group size above — a gang must
                // not become permanently "incomplete" (and unadmittable)
                // because one member already finished.
                if workload_terminal(&obj) {
                    continue;
                }
                let Ok(demand) = workload_demand(&obj) else {
                    // An undecodable member can never be admitted, so its
                    // whole gang must be held — admitting the decodable
                    // remainder would be a partial gang.
                    self.metrics.inc("kueue.undecodable_workload");
                    poisoned.insert(key);
                    continue;
                };
                let priority = workload_priority(&obj);
                let g = gangs
                    .entry((is_admitted(&obj), key.0, key.1))
                    .or_insert_with(|| PendingGang {
                        members: Vec::new(),
                        member_demands: Vec::new(),
                        member_traces: Vec::new(),
                        cq,
                        label: label.clone(),
                        demand: QueueResources::ZERO,
                        priority,
                        uid: obj.meta.uid,
                        complete: true,
                        trace: None,
                    });
                let member_trace =
                    obj.meta.annotation(crate::obs::TRACE_ANNOTATION).map(String::from);
                if g.trace.is_none() {
                    g.trace = member_trace
                        .as_deref()
                        .and_then(crate::obs::TraceContext::parse_wire);
                }
                g.members.push((obj.kind.clone(), obj.meta.name.clone()));
                g.member_demands.push(demand);
                g.member_traces.push(member_trace);
                g.demand = g.demand.saturating_add(&demand);
                g.priority = g.priority.max(priority);
                g.uid = g.uid.min(obj.meta.uid);
            }
        }

        // ---- split admitted / pending -------------------------------
        // Admitted gangs feed the preemption search and the counts; their
        // demand is *already* charged in the incremental ledger. Pending
        // gangs get their completeness verdict (all declared members
        // present, admitted + pending + terminal).
        let mut admitted: Vec<AdmittedGang> = Vec::new();
        let mut pending_gangs: Vec<PendingGang> = Vec::new();
        for ((is_adm, label, group), mut gang) in gangs {
            if is_adm {
                admitted.push(AdmittedGang {
                    members: gang.members,
                    queue: gang.cq,
                    label: gang.label,
                    demand: gang.demand,
                    priority: gang.priority,
                    uid: gang.uid,
                });
            } else {
                let grouped = !group.starts_with("__solo/");
                let key = (label, group);
                gang.complete = !poisoned.contains(&key)
                    && match declared_counts.get(&key) {
                        Some(declared) => {
                            group_sizes.get(&key).copied().unwrap_or(0) >= *declared
                        }
                        // A grouped gang whose declared size is not yet
                        // known (the annotated member hasn't been created)
                        // must be held — admitting early members one by one
                        // is exactly the partial admission gangs exist to
                        // prevent. Solo workloads carry no annotation and
                        // are always ready.
                        None => !grouped,
                    };
                pending_gangs.push(gang);
            }
        }

        // ---- admit, strictly ordered per queue ----------------------
        let mut report = CycleReport::default();
        let mut pending: Vec<PendingGang> = pending_gangs;
        let mut blocked_now: std::collections::BTreeSet<(String, u64)> =
            std::collections::BTreeSet::new();
        for cq in &cqs {
            let mut queue_gangs: Vec<&PendingGang> =
                pending.iter().filter(|g| g.cq == cq.name).collect();
            match cq.ordering {
                QueueOrdering::Fifo => queue_gangs.sort_by_key(|g| g.uid),
                QueueOrdering::Priority => {
                    queue_gangs.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.uid.cmp(&b.uid)))
                }
            }
            let mut decisions: Vec<PendingGang> = Vec::new();
            for gang in queue_gangs {
                if !gang.complete {
                    continue; // waiting for members; does not block the queue
                }
                // A member already holding a ledger charge means this
                // "pending" gang is a stale read: we admitted it in an
                // earlier cycle and the cache has not yet received the
                // Admitted echo (possible over the lagging remote
                // transport). Charging again would leak quota headroom
                // permanently — the later echo no-ops against the charge
                // map. Skip; the next cycle sees it admitted. (Eviction
                // removes charges, so re-admission is never blocked.)
                if gang.members.iter().any(|m| st.charged.contains_key(m)) {
                    self.metrics.inc("kueue.stale_pending_skipped");
                    continue;
                }
                let fit = st.ledger.fit(&cq.name, &gang.demand);
                match fit {
                    Fit::Ok { borrowed } => {
                        if borrowed {
                            self.metrics.inc("kueue.admitted_borrowing");
                        }
                        st.ledger.charge(&cq.name, &gang.demand);
                        decisions.push(gang.clone());
                    }
                    Fit::BlockedWithinNominal => {
                        let Some(victims) = select_victims(
                            &st.ledger,
                            &admitted,
                            cq,
                            &gang.demand,
                            gang.priority,
                        ) else {
                            self.note_quota_exhausted(
                                api,
                                gang,
                                &cq.name,
                                "no preemptable lower-priority workloads",
                                &mut blocked_now,
                            );
                            break; // strict: a blocked head holds the queue
                        };
                        let mut budget_blocked = false;
                        for v in &victims {
                            if let Err(e) = evict_gang(api, v) {
                                // A PodDisruptionBudget vetoed a victim:
                                // this gang cannot be preempted for this
                                // cycle. Not an error — the budget may
                                // loosen (pods finish, replicas come up)
                                // and the head retries next cycle.
                                if e.is_disruption_budget_exceeded() {
                                    self.metrics.inc("kueue.preemption_budget_blocked");
                                    self.note_quota_exhausted(
                                        api,
                                        gang,
                                        &cq.name,
                                        &format!("preemption blocked: {e}"),
                                        &mut blocked_now,
                                    );
                                    budget_blocked = true;
                                    break;
                                }
                                return Err(e);
                            }
                            // Uncharge through the per-member charge map
                            // (idempotent with the eviction's echo events
                            // next cycle).
                            for m in &v.members {
                                let trace = api.get(&m.0, &m.1).ok().and_then(|o| {
                                    o.meta
                                        .annotation(crate::obs::TRACE_ANNOTATION)
                                        .map(String::from)
                                });
                                let _ = self.events.event_ref(
                                    api,
                                    &m.0,
                                    &m.1,
                                    trace.as_deref(),
                                    EVENT_WARNING,
                                    "Evicted",
                                    &format!(
                                        "Preempted from ClusterQueue {} by higher-priority gang {}",
                                        cq.name, gang.label
                                    ),
                                );
                                Self::apply_delta(&mut st, m.clone(), None);
                            }
                            report.preempted += v.members.len();
                            self.metrics.inc("kueue.gangs_preempted");
                        }
                        if budget_blocked {
                            break; // strict: a blocked head holds the queue
                        }
                        admitted.retain(|a| !victims.contains(a));
                        st.ledger.charge(&cq.name, &gang.demand);
                        decisions.push(gang.clone());
                    }
                    Fit::Blocked => {
                        self.note_quota_exhausted(
                            api,
                            gang,
                            &cq.name,
                            "demand exceeds borrowable quota",
                            &mut blocked_now,
                        );
                        break;
                    }
                    Fit::UnknownQueue => break,
                }
            }
            for (i, gang) in decisions.iter().enumerate() {
                // Parent the admission write on the workload's originating
                // trace, so create → admit reads as one causal chain.
                let _span = crate::obs::span_with_parent(
                    "kueue",
                    &format!("admit {}", gang.label),
                    gang.trace,
                );
                if let Err(e) = self.admit(api, &gang.members, &cq.name) {
                    // The selection walk already charged every decision;
                    // the failed gang and everything after it will not
                    // admit this cycle — release their reservations so
                    // the persistent ledger stays truthful.
                    for g in &decisions[i..] {
                        st.ledger.uncharge(&cq.name, &g.demand);
                    }
                    return Err(e);
                }
                report.admitted += gang.members.len();
                self.metrics.inc("kueue.gangs_admitted");
                let note = format!(
                    "Admitted by ClusterQueue {} (gang {}, {} member(s), demand {})",
                    cq.name,
                    gang.label,
                    gang.members.len(),
                    fmt_demand(&gang.demand),
                );
                for ((kind, name), trace) in gang.members.iter().zip(&gang.member_traces)
                {
                    let _ = self.events.event_ref(
                        api,
                        kind,
                        name,
                        trace.as_deref(),
                        EVENT_NORMAL,
                        "Admitted",
                        &note,
                    );
                }
                // Record the per-member charges (the ledger was charged
                // during selection; the map entry makes the admission's
                // own echo events no-ops next cycle).
                for (m, d) in gang.members.iter().zip(&gang.member_demands) {
                    st.charged.insert(m.clone(), (cq.name.clone(), *d));
                }
                // Move into the admitted set so counts (and later queues'
                // preemption searches) see it; drop from pending.
                pending.retain(|g| g.members != gang.members);
                admitted.push(AdmittedGang {
                    members: gang.members.clone(),
                    queue: gang.cq.clone(),
                    label: gang.label.clone(),
                    demand: gang.demand,
                    priority: gang.priority,
                    uid: gang.uid,
                });
            }
        }
        report.pending = pending.iter().map(|g| g.members.len()).sum();
        // Edge-trigger baseline for the next cycle: gangs that stopped
        // being blocked (admitted, deleted, resized) drop out and may
        // report QuotaExhausted afresh if they block again later.
        *self.blocked_noted.lock().unwrap() = blocked_now;

        // ---- queue status counts (write only on change) --------------
        let mut cq_counts: BTreeMap<&str, (u64, u64)> = BTreeMap::new();
        let mut lq_counts: BTreeMap<&str, (u64, u64)> = BTreeMap::new();
        for g in &pending {
            count_into(&mut cq_counts, &g.cq, g.members.len() as u64, 0);
            if lqs.iter().any(|l| l.name == g.label) {
                count_into(&mut lq_counts, &g.label, g.members.len() as u64, 0);
            }
        }
        for g in &admitted {
            count_into(&mut cq_counts, &g.queue, 0, g.members.len() as u64);
            if lqs.iter().any(|l| l.name == g.label) {
                count_into(&mut lq_counts, &g.label, 0, g.members.len() as u64);
            }
        }
        for cq in &cqs {
            let (p, a) = cq_counts.get(cq.name.as_str()).copied().unwrap_or((0, 0));
            if cq.pending != p || cq.admitted != a {
                update_counts(api, KIND_CLUSTERQUEUE, &cq.name, p, a)?;
            }
        }
        for lq in &lqs {
            let (p, a) = lq_counts.get(lq.name.as_str()).copied().unwrap_or((0, 0));
            if lq.pending != p || lq.admitted != a {
                update_counts(api, KIND_LOCALQUEUE, &lq.name, p, a)?;
            }
        }

        self.metrics.observe("kueue.cycle_ns", t0.elapsed().as_nanos() as u64);
        Ok(report)
    }

    /// Emit a Warning `QuotaExhausted` event for every member of a
    /// blocked gang — what `kubectl describe` surfaces for a workload
    /// stuck at the head of its queue. Edge-triggered via
    /// [`AdmissionCore::blocked_noted`]: a gang that stays blocked across
    /// cycles writes nothing after the first report.
    fn note_quota_exhausted(
        &self,
        api: &dyn ApiClient,
        gang: &PendingGang,
        cq: &str,
        why: &str,
        blocked_now: &mut std::collections::BTreeSet<(String, u64)>,
    ) {
        let key = (cq.to_string(), gang.uid);
        let already = self.blocked_noted.lock().unwrap().contains(&key);
        blocked_now.insert(key);
        if already {
            return;
        }
        let note = format!(
            "ClusterQueue {cq} cannot fit gang {} ({} member(s), demand {}): {why}",
            gang.label,
            gang.members.len(),
            fmt_demand(&gang.demand),
        );
        for ((kind, name), trace) in gang.members.iter().zip(&gang.member_traces) {
            let _ = self.events.event_ref(
                api,
                kind,
                name,
                trace.as_deref(),
                EVENT_WARNING,
                "QuotaExhausted",
                &note,
            );
        }
    }

    /// Flip a whole gang's members to admitted, stamping the ClusterQueue
    /// their demand is charged to. Only called after the full gang was
    /// reserved in the ledger — this write order is what the
    /// "all-or-nothing" guarantee rests on.
    fn admit(&self, api: &dyn ApiClient, members: &[(String, String)], cq: &str) -> Result<()> {
        for (i, (kind, name)) in members.iter().enumerate() {
            let res = api.update_status(kind, name, &|o| {
                set_condition(&mut o.status, COND_QUOTA_RESERVED, true);
                set_condition(&mut o.status, COND_ADMITTED, true);
                set_condition(&mut o.status, COND_EVICTED, false);
                o.status.insert("clusterQueue", cq);
                // Admission is what releases the pod to the scheduler.
                remove_scheduling_gate(o, SCHEDULING_GATE);
            });
            match res {
                Ok(_) => {}
                // Deleted between list and write: its charge vanishes
                // next cycle; nothing to unwind.
                Err(e) if e.is_not_found() => {}
                Err(e) => {
                    // Best-effort unwind: a partially-admitted gang must
                    // not survive the cycle — the reservation lives only
                    // in this cycle's ledger, so stranded members would
                    // run while the remainder can never re-fit. Roll the
                    // already-written members back to suspended.
                    for (k, n) in &members[..i] {
                        let _ = api.update_status(k, n, &|o| {
                            set_condition(&mut o.status, COND_ADMITTED, false);
                            set_condition(&mut o.status, COND_QUOTA_RESERVED, false);
                            o.status.remove("clusterQueue");
                            if o.kind == KIND_POD {
                                add_scheduling_gate(o, SCHEDULING_GATE);
                            }
                        });
                    }
                    self.metrics.inc("kueue.admit_unwound");
                    return Err(e);
                }
            }
        }
        Ok(())
    }
}

/// Human rendering of a gang demand for event notes — only the bounded
/// dimensions (node-only quotas leave cpu/mem at `u64::MAX`).
fn fmt_demand(d: &QueueResources) -> String {
    let mut parts = Vec::new();
    if d.nodes > 0 && d.nodes < u32::MAX {
        parts.push(format!("{} node(s)", d.nodes));
    }
    if d.cpu_milli > 0 && d.cpu_milli < u64::MAX {
        parts.push(format!("{}m CPU", d.cpu_milli));
    }
    if d.mem_bytes > 0 && d.mem_bytes < u64::MAX {
        parts.push(format!("{}B memory", d.mem_bytes));
    }
    if parts.is_empty() {
        "none".to_string()
    } else {
        parts.join(", ")
    }
}

fn annotation<'a>(obj: &'a KubeObject, key: &str) -> Option<&'a str> {
    obj.meta.annotations.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn count_into<'a>(
    counts: &mut BTreeMap<&'a str, (u64, u64)>,
    key: &'a str,
    pending: u64,
    admitted: u64,
) {
    let slot = counts.entry(key).or_insert((0, 0));
    slot.0 += pending;
    slot.1 += admitted;
}

fn update_counts(
    api: &dyn ApiClient,
    kind: &str,
    name: &str,
    pending: u64,
    admitted: u64,
) -> Result<()> {
    match api.update_status(kind, name, &|o| {
        o.status.insert("pending", pending);
        o.status.insert("admitted", admitted);
    }) {
        Ok(_) => Ok(()),
        Err(e) if e.is_not_found() => Ok(()),
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resources;
    use crate::kube::{ApiServer, PodView, KIND_POD};
    use crate::kueue::types::QUEUE_NAME_LABEL;

    fn api() -> ApiServer {
        ApiServer::new(Metrics::new())
    }

    fn core_for(api: &ApiServer) -> AdmissionCore {
        let informers =
            crate::kube::SharedInformerFactory::new(api.client(), Metrics::new());
        AdmissionCore::new(&informers, Metrics::new())
    }

    fn labelled_pod(name: &str, queue: &str, cpu: u64) -> KubeObject {
        let mut p = PodView::build(name, "img.sif", Resources::new(cpu, 1 << 20, 0), &[]);
        p.meta.set_label(QUEUE_NAME_LABEL, queue);
        p
    }

    #[test]
    fn unlabelled_workloads_ignored_and_unknown_queue_held() {
        let a = api();
        let core = core_for(&a);
        a.create(PodView::build("plain", "img.sif", Resources::ZERO, &[])).unwrap();
        a.create(labelled_pod("orphan", "no-such-queue", 100)).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r, CycleReport::default(), "nothing admitted, nothing counted");
        assert!(!is_admitted(&a.get(KIND_POD, "orphan").unwrap()));
        assert!(!is_admitted(&a.get(KIND_POD, "plain").unwrap()));
    }

    #[test]
    fn admits_within_quota_and_reports_counts() {
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build("cq-a", QueueResources::nodes(2))).unwrap();
        a.create(LocalQueueView::build("team", "cq-a")).unwrap();
        for i in 0..3 {
            a.create(labelled_pod(&format!("p{i}"), "team", 100)).unwrap();
        }
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 2, "FIFO: first two fit the 2-node quota");
        assert_eq!(r.pending, 1);
        assert!(is_admitted(&a.get(KIND_POD, "p0").unwrap()));
        assert!(is_admitted(&a.get(KIND_POD, "p1").unwrap()));
        assert!(!is_admitted(&a.get(KIND_POD, "p2").unwrap()));
        // Status counts landed on both queue objects.
        let cq = ClusterQueueView::from_object(&a.get(KIND_CLUSTERQUEUE, "cq-a").unwrap()).unwrap();
        assert_eq!((cq.pending, cq.admitted), (1, 2));
        let lq = LocalQueueView::from_object(&a.get(KIND_LOCALQUEUE, "team").unwrap()).unwrap();
        assert_eq!(lq.pending, 1);
        // A second cycle is a no-op (stability: no write storms).
        let v = a.current_version();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 0);
        assert_eq!(a.current_version(), v, "steady state writes nothing");
        // Completion releases quota for the straggler.
        a.update_status(KIND_POD, "p0", |o| o.status.insert("phase", "Succeeded")).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1);
        assert!(is_admitted(&a.get(KIND_POD, "p2").unwrap()));
    }

    #[test]
    fn queue_label_removal_uncharges_without_rebuild() {
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build("cq-a", QueueResources::nodes(1))).unwrap();
        a.create(LocalQueueView::build("team", "cq-a")).unwrap();
        a.create(labelled_pod("first", "team", 100)).unwrap();
        assert_eq!(core.cycle(&a).unwrap().admitted, 1);
        let rebuilds = core.ledger_rebuilds();

        // Strip the queue label from the admitted workload: the informer
        // still delivers that transition (it matched the pre-event
        // labels), charge_entry returns None, and apply_delta releases
        // the charge — incrementally, not via rebuild.
        let mut stripped = a.get(KIND_POD, "first").unwrap();
        stripped.meta.labels.retain(|(k, _)| k != QUEUE_NAME_LABEL);
        a.update(stripped).unwrap();
        a.create(labelled_pod("second", "team", 100)).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1, "freed quota admits the newcomer");
        assert!(is_admitted(&a.get(KIND_POD, "second").unwrap()));
        assert_eq!(
            core.ledger_rebuilds(),
            rebuilds,
            "label removal must uncharge without a ledger rebuild"
        );
    }

    #[test]
    fn direct_cluster_queue_label_resolves() {
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build("cq-direct", QueueResources::nodes(1))).unwrap();
        a.create(labelled_pod("p", "cq-direct", 100)).unwrap();
        assert_eq!(core.cycle(&a).unwrap().admitted, 1);
    }

    #[test]
    fn strict_fifo_blocks_behind_wide_gang() {
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build("cq", QueueResources::nodes(3))).unwrap();
        // Head gang needs 2 nodes via a pod group; only 1 node free after
        // an earlier admission -> the whole queue waits behind it.
        a.create(labelled_pod("first", "cq", 100)).unwrap();
        a.create(labelled_pod("second", "cq", 100)).unwrap();
        assert_eq!(core.cycle(&a).unwrap().admitted, 2); // 1 node left
        let mut g0 = labelled_pod("wide-0", "cq", 100);
        g0.meta.set_label(POD_GROUP_LABEL, "wide");
        g0.meta
            .annotations
            .push((POD_GROUP_COUNT_ANNOTATION.to_string(), "2".to_string()));
        let mut g1 = labelled_pod("wide-1", "cq", 100);
        g1.meta.set_label(POD_GROUP_LABEL, "wide");
        a.create(g0).unwrap();
        a.create(g1).unwrap();
        a.create(labelled_pod("small", "cq", 100)).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 0, "wide gang blocked; strict FIFO holds `small` too");
        assert_eq!(r.pending, 3);
        assert!(!is_admitted(&a.get(KIND_POD, "small").unwrap()));
    }

    #[test]
    fn group_without_declared_count_is_held() {
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build("cq", QueueResources::nodes(10))).unwrap();
        // First member arrives WITHOUT the count annotation (the docs
        // allow it on any member): the group must be held, not admitted
        // one member at a time.
        let mut g0 = labelled_pod("h-0", "cq", 100);
        g0.meta.set_label(POD_GROUP_LABEL, "h");
        a.create(g0).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 0, "unknown gang size: held");
        // The annotated member lands: both admit together.
        let mut g1 = labelled_pod("h-1", "cq", 100);
        g1.meta.set_label(POD_GROUP_LABEL, "h");
        g1.meta
            .annotations
            .push((POD_GROUP_COUNT_ANNOTATION.to_string(), "2".to_string()));
        a.create(g1).unwrap();
        assert_eq!(core.cycle(&a).unwrap().admitted, 2);
    }

    #[test]
    fn completed_group_member_still_counts_for_completeness() {
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build("cq", QueueResources::nodes(2))).unwrap();
        for i in 0..2 {
            let mut g = labelled_pod(&format!("g-{i}"), "cq", 100);
            g.meta.set_label(POD_GROUP_LABEL, "g");
            g.meta
                .annotations
                .push((POD_GROUP_COUNT_ANNOTATION.to_string(), "2".to_string()));
            a.create(g).unwrap();
        }
        assert_eq!(core.cycle(&a).unwrap().admitted, 2);
        // g-0 finishes; g-1 loses its admission (eviction shape). The
        // survivor must re-admit: the finished member still counts toward
        // the declared group size.
        a.update_status(KIND_POD, "g-0", |o| o.status.insert("phase", "Succeeded")).unwrap();
        a.update_status(KIND_POD, "g-1", |o| {
            set_condition(&mut o.status, COND_ADMITTED, false);
        })
        .unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1, "remainder of a partially-completed gang re-admits");
        assert!(is_admitted(&a.get(KIND_POD, "g-1").unwrap()));
    }

    #[test]
    fn scheduling_gate_backfilled_then_cleared_on_admission() {
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build("cq", QueueResources::nodes(1))).unwrap();
        // Born gated through the builder.
        let mut first = PodView::build("first", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
        crate::kueue::queue_workload(&mut first, "cq");
        a.create(first).unwrap();
        // Created with a bare label (no gate): the cycle back-fills it.
        a.create(labelled_pod("second", "cq", 100)).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1, "1-node quota admits only the head");
        let first = a.get(KIND_POD, "first").unwrap();
        assert!(is_admitted(&first));
        assert!(
            crate::kube::scheduling_gates(&first).is_empty(),
            "admission clears the gate"
        );
        let second = a.get(KIND_POD, "second").unwrap();
        assert!(!is_admitted(&second));
        assert_eq!(
            crate::kube::scheduling_gates(&second),
            vec![crate::kueue::SCHEDULING_GATE.to_string()],
            "suspended straggler gets the gate back-filled"
        );
    }

    #[test]
    fn admission_emits_admitted_and_quota_exhausted_events() {
        use crate::kube::events::{EventView, KIND_EVENT};
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build("cq-a", QueueResources::nodes(1))).unwrap();
        a.create(LocalQueueView::build("team", "cq-a")).unwrap();
        a.create(labelled_pod("first", "team", 100)).unwrap();
        a.create(labelled_pod("second", "team", 100)).unwrap();
        core.cycle(&a).unwrap();
        let evs = |reason: &str| -> Vec<EventView> {
            a.list(KIND_EVENT, &[])
                .iter()
                .map(|o| EventView::from_object(o).unwrap())
                .filter(|e| e.reason == reason)
                .collect()
        };
        let adm = evs("Admitted");
        assert_eq!(adm.len(), 1, "one admitted member, one Admitted event");
        assert_eq!(adm[0].regarding_name, "first");
        assert_eq!(adm[0].etype, EVENT_NORMAL);
        assert_eq!(adm[0].reporting_controller, COMPONENT);
        assert!(adm[0].note.contains("cq-a"), "note names the ClusterQueue");
        let blocked = evs("QuotaExhausted");
        assert_eq!(blocked.len(), 1, "head-of-line blockage reported");
        assert_eq!(blocked[0].regarding_name, "second");
        assert_eq!(blocked[0].etype, EVENT_WARNING);
        assert!(blocked[0].note.contains("1 node(s)"), "note carries the demand math");
        // Still-blocked gangs are edge-triggered: a second cycle must not
        // re-emit (or bump) QuotaExhausted — steady state writes nothing.
        let v = a.current_version();
        core.cycle(&a).unwrap();
        assert_eq!(a.current_version(), v, "steady state stays write-free");
        // The audit trail attributes this cycle's writes to kueue.
        assert!(a
            .audit_log()
            .snapshot()
            .iter()
            .any(|r| r.actor == COMPONENT && r.verb == "update_status"));
    }

    #[test]
    fn priority_ordering_reorders_admission() {
        use crate::kueue::types::{PreemptionPolicy, PRIORITY_LABEL};
        let a = api();
        let core = core_for(&a);
        a.create(ClusterQueueView::build_full(
            "cq",
            None,
            QueueResources::nodes(1),
            None,
            QueueOrdering::Priority,
            PreemptionPolicy::default(),
        ))
        .unwrap();
        a.create(labelled_pod("old-low", "cq", 100)).unwrap();
        let mut vip = labelled_pod("new-high", "cq", 100);
        vip.meta.set_label(PRIORITY_LABEL, "5");
        a.create(vip).unwrap();
        let r = core.cycle(&a).unwrap();
        assert_eq!(r.admitted, 1);
        assert!(is_admitted(&a.get(KIND_POD, "new-high").unwrap()), "priority jumps FIFO");
        assert!(!is_admitted(&a.get(KIND_POD, "old-low").unwrap()));
    }
}
