//! Observability-layer overhead (PR 7): what tracing and metric
//! exposition cost the hot paths.
//!
//! - `obs/span_record`: open+close one span (the per-operation cost every
//!   instrumented call site pays while tracing is on).
//! - `obs/span_disabled`: the same call with tracing off — one atomic
//!   load; this is the price the whole fleet pays when nobody is looking.
//! - `obs/nested_span_x8`: an 8-deep child chain (a worst-case causal
//!   tree step, e.g. CLI → redbox → apiserver → store).
//! - `obs/prom_render_10k`: render a 10k-metric registry to Prometheus
//!   text (one full scrape).
//! - `obs/json_snapshot_10k`: same registry as the structured snapshot.
//!
//! Prints `{"bench":...}` JSON rows for the CI perf trajectory.

use hpcorc::bench::{header, Bench};
use hpcorc::cluster::Metrics;
use hpcorc::obs;

fn main() {
    println!("== observability overhead (PR 7) ==");
    println!("{}", header());
    let mut rows = Vec::new();

    // Per-span record cost, tracing on.
    obs::set_enabled(true);
    obs::clear();
    rows.push(Bench::new("obs/span_record").warmup(1000).iters(20_000).run(|| {
        let _g = obs::span("bench", "op");
    }));

    // Disabled path: the guard must be near-free.
    obs::set_enabled(false);
    rows.push(Bench::new("obs/span_disabled").warmup(1000).iters(20_000).run(|| {
        let _g = obs::span("bench", "op");
    }));
    obs::set_enabled(true);

    // Nested chain: stack push/pop + parent linkage, 8 levels.
    rows.push(Bench::new("obs/nested_span_x8").warmup(100).iters(5_000).run(|| {
        let _a = obs::span("bench", "l0");
        let _b = obs::span("bench", "l1");
        let _c = obs::span("bench", "l2");
        let _d = obs::span("bench", "l3");
        let _e = obs::span("bench", "l4");
        let _f = obs::span("bench", "l5");
        let _g = obs::span("bench", "l6");
        let _h = obs::span("bench", "l7");
    }));

    // A populated registry: 10k metrics split across the three families,
    // histograms fed enough samples to spread over buckets.
    let m = Metrics::new();
    for i in 0..6000u64 {
        m.add(&format!("bench.counter.{i:04}"), i);
    }
    for i in 0..2000i64 {
        m.set_gauge(&format!("bench.gauge.{i:04}"), i - 1000);
    }
    for i in 0..2000u64 {
        let name = format!("bench.hist.{i:04}");
        for s in [100, 5_000, 250_000, 10_000_000] {
            m.observe(&name, s + i);
        }
    }
    rows.push(Bench::new("obs/prom_render_10k").warmup(2).iters(20).run(|| {
        std::hint::black_box(obs::render_prom(&m));
    }));
    rows.push(Bench::new("obs/json_snapshot_10k").warmup(2).iters(20).run(|| {
        std::hint::black_box(obs::render_json(&m));
    }));

    println!();
    for s in &rows {
        println!("{}", s.json());
    }

    // Guardrail, not a flaky assert: the disabled path must be far
    // cheaper than recording. A regression here means someone put work
    // in front of the enabled() check.
    let record = rows[0].mean_ns;
    let disabled = rows[1].mean_ns;
    if disabled * 10.0 > record + 1.0 {
        eprintln!(
            "warning: disabled span path ({disabled:.0}ns) is not ~free vs record ({record:.0}ns)"
        );
    }
}
