//! The operator core — the paper's contribution.
//!
//! One controller drives a `TorqueJob` (or `SlurmJob`) through the flow of
//! paper §III-B / Fig. 2:
//!
//! 1. **dummy pod** `<job>-submit` is created with the `virtual-kubelet`
//!    toleration and a nodeSelector for the target queue's virtual node;
//!    the *Kubernetes* scheduler places it (this is the "containerised
//!    applications can be better scheduled … by taking advantage of the
//!    scheduling policies of Kubernetes" hook).
//! 2. once the dummy pod is bound, the embedded batch script is submitted
//!    through red-box (`qsub` / `sbatch`) and the WLM job id recorded in
//!    `status.jobId`.
//! 3. the operator polls job status over red-box and mirrors it into
//!    `status.phase` (what `kubectl get torquejob` shows, Fig. 4).
//! 4. on completion a **results pod** `<job>-collect` stages
//!    `spec.results.from` into the directory from `spec.mount.hostPath`
//!    (Fig. 5), then the job object reaches `completed`.

use super::redbox_svc::{WlmBridge, WlmStatus};
use super::virtual_node::{LABEL_QUEUE, LABEL_WLM, VIRTUAL_KUBELET_TAINT};
use crate::cluster::{Metrics, Resources};
use crate::encoding::Value;
use crate::kube::scheduler::pod_with_tolerations;
use crate::kube::{
    ApiClient, Controller, EventRecorder, PodView, Reconcile, WlmJobView, EVENT_NORMAL,
    EVENT_WARNING, KIND_POD,
};
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Component name stamped on events and audit records this controller
/// writes.
const COMPONENT: &str = "kube-operator";

/// Operator phases surfaced in `status.phase` (lowercase as in Fig. 4).
pub mod phase {
    pub const PENDING: &str = "pending";
    pub const QUEUED: &str = "queued";
    pub const RUNNING: &str = "running";
    pub const TRANSFERRING: &str = "transferring-results";
    pub const COMPLETED: &str = "completed";
    pub const FAILED: &str = "failed";
    pub const CANCELLED: &str = "cancelled";
    pub const TIMEOUT: &str = "timeout";

    pub fn terminal(p: &str) -> bool {
        matches!(p, COMPLETED | FAILED | CANCELLED | TIMEOUT)
    }
}

/// How the operator extracts the destination queue from the batch script.
pub type QueueExtractor = fn(&str) -> Option<String>;

pub fn torque_queue_extractor(script: &str) -> Option<String> {
    crate::pbs::PbsScript::parse(script).ok().and_then(|s| s.queue)
}

pub fn slurm_queue_extractor(script: &str) -> Option<String> {
    crate::slurm::SlurmScript::parse(script).ok().and_then(|s| s.partition)
}

pub struct OperatorConfig {
    /// CRD kind handled (`TorqueJob` / `SlurmJob`).
    pub kind: &'static str,
    /// WLM backend name for labels (`torque` / `slurm`).
    pub wlm: &'static str,
    /// Poll interval for WLM job status.
    pub poll: Duration,
    pub queue_extractor: QueueExtractor,
}

impl OperatorConfig {
    pub fn torque() -> Self {
        OperatorConfig {
            kind: crate::kube::KIND_TORQUEJOB,
            wlm: "torque",
            // Perf pass (EXPERIMENTS.md §Perf): 5ms → 1ms poll cut mean
            // operator overhead ~9ms → ~3ms/job; red-box JobStatus costs
            // ~10µs, so polling at 1ms adds negligible login-node load.
            poll: Duration::from_millis(1),
            queue_extractor: torque_queue_extractor,
        }
    }

    pub fn slurm() -> Self {
        OperatorConfig {
            kind: crate::kube::KIND_SLURMJOB,
            wlm: "slurm",
            poll: Duration::from_millis(1),
            queue_extractor: slurm_queue_extractor,
        }
    }
}

/// The operator (generic over the WLM bridge). `TorqueOperator` and
/// `WlmOperator` (Slurm) are this type with different configs.
pub struct WlmJobOperator {
    config: OperatorConfig,
    bridge: Arc<dyn WlmBridge>,
    /// name → WLM job id, for cancellation when the object is deleted.
    tracked: Mutex<HashMap<String, String>>,
    events: EventRecorder,
    metrics: Metrics,
}

impl WlmJobOperator {
    pub fn new(
        config: OperatorConfig,
        bridge: Arc<dyn WlmBridge>,
        metrics: Metrics,
    ) -> Arc<Self> {
        Arc::new(WlmJobOperator {
            config,
            bridge,
            tracked: Mutex::new(HashMap::new()),
            events: EventRecorder::new(COMPONENT, metrics.clone()),
            metrics,
        })
    }

    fn dummy_pod_name(job: &str) -> String {
        format!("{job}-submit")
    }

    fn results_pod_name(job: &str) -> String {
        format!("{job}-collect")
    }

    /// Create the dummy pod targeting the queue's virtual node.
    fn create_dummy_pod(&self, api: &dyn ApiClient, job: &WlmJobView, queue: &str) -> Result<()> {
        let name = Self::dummy_pod_name(&job.name);
        let mut pod = pod_with_tolerations(
            PodView::build(&name, "wlm-dummy.sif", Resources::new(1, 1 << 20, 0), &[]),
            &[VIRTUAL_KUBELET_TAINT],
        );
        pod.spec.insert(
            "nodeSelector",
            Value::map()
                .with(LABEL_QUEUE, queue)
                .with(LABEL_WLM, self.config.wlm),
        );
        pod.meta.set_label("wlm-job", &job.name);
        pod.meta.owner = Some((self.config.kind.to_string(), job.name.clone()));
        match api.create(pod) {
            Ok(_) => Ok(()),
            Err(ref e) if matches!(e, Error::Api(crate::util::ApiError::AlreadyExists { .. })) => {
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    /// Stage results: read `results.from` from the WLM cluster and write it
    /// into the hostPath directory, via a results pod object (the paper's
    /// second dummy pod).
    fn collect_results(&self, api: &dyn ApiClient, job: &WlmJobView) -> Result<()> {
        let (Some(from), Some(mount)) = (&job.results_from, &job.mount_path) else {
            return Ok(()); // nothing requested
        };
        let pod_name = Self::results_pod_name(&job.name);
        let mut pod = pod_with_tolerations(
            PodView::build(&pod_name, "wlm-collect.sif", Resources::new(1, 1 << 20, 0), &[]),
            &[VIRTUAL_KUBELET_TAINT],
        );
        pod.meta.set_label("wlm-job", &job.name);
        pod.meta.owner = Some((self.config.kind.to_string(), job.name.clone()));
        let _ = api.create(pod); // AlreadyExists ok (retry path)
        let content = self.bridge.read_file(from)?;
        let base = from.trim_end_matches('/').rsplit('/').next().unwrap_or("results.out");
        let target = if mount.ends_with('/') {
            format!("{mount}{base}")
        } else {
            format!("{mount}/{base}")
        };
        self.bridge.write_file(&target, &content)?;
        let _ = api.update_status(KIND_POD, &pod_name, &|o| {
            o.status.insert("phase", "Succeeded");
            o.status.insert("log", format!("staged {from} -> {target}"));
        });
        self.metrics.inc("operator.results_collected");
        Ok(())
    }

    fn set_phase(&self, api: &dyn ApiClient, name: &str, phase: &str) -> Result<()> {
        api.update_status(self.config.kind, name, &|o| {
            o.status.insert("phase", phase);
        })?;
        Ok(())
    }
}

impl Controller for WlmJobOperator {
    fn kind(&self) -> &str {
        self.config.kind
    }

    fn reconcile(&self, api: &dyn ApiClient, name: &str) -> Result<Reconcile> {
        // Every write this pass makes is attributed to the operator in the
        // API server's audit trail (PR 8).
        let _actor = crate::obs::push_actor(COMPONENT);
        let obj = match api.get(self.config.kind, name) {
            Ok(o) => o,
            Err(e) if e.is_not_found() => {
                // Object deleted: cancel the WLM job if still tracked.
                if let Some(job_id) = self.tracked.lock().unwrap().remove(name) {
                    let _ = self.bridge.cancel(&job_id);
                    self.metrics.inc("operator.cancelled_on_delete");
                }
                return Ok(Reconcile::Ok);
            }
            Err(e) => return Err(e),
        };
        let view = WlmJobView::from_object(&obj)?;

        // Queue layer (PR 2): a job that opted into quota admission is
        // held suspended until admitted, and — if preempted mid-flight —
        // cancelled over red-box and reset so it resubmits on
        // re-admission (the gang either holds its full reservation or
        // nothing of it runs).
        if crate::kueue::admission_gated(&obj) {
            match view.status.as_str() {
                // Nothing created yet: stay suspended.
                "" => {
                    self.metrics.inc("operator.kueue_suspended");
                    return Ok(Reconcile::RequeueAfter(self.config.poll));
                }
                // Evicted after the flow started: unwind the submission.
                phase::PENDING | phase::QUEUED | phase::RUNNING => {
                    if let Some(job_id) = &view.wlm_job_id {
                        let _ = self.bridge.cancel(job_id);
                    }
                    self.tracked.lock().unwrap().remove(name);
                    // Tear down the dummy pod too: re-admission must re-run
                    // the placement gate (fresh pod, fresh scheduling pass)
                    // rather than trust a stale binding to a virtual node
                    // that may no longer exist.
                    let _ = api.delete(KIND_POD, &Self::dummy_pod_name(name));
                    api.update_status(self.config.kind, name, &|o| {
                        o.status.insert("phase", "");
                        o.status.remove("jobId");
                    })?;
                    self.metrics.inc("operator.kueue_preempted");
                    return Ok(Reconcile::RequeueAfter(self.config.poll));
                }
                // Terminal / transferring: eviction is moot.
                _ => {}
            }
        }

        match view.status.as_str() {
            // New object: create the dummy pod on the queue's virtual node.
            "" => {
                let queue = (self.config.queue_extractor)(&view.batch)
                    .or_else(|| self.bridge.queues().ok().and_then(|q| q.first().cloned()))
                    .ok_or_else(|| Error::wlm("no destination queue"))?;
                self.create_dummy_pod(api, &view, &queue)?;
                self.set_phase(api, name, phase::PENDING)?;
                self.metrics.inc("operator.jobs_admitted");
                Ok(Reconcile::RequeueAfter(self.config.poll))
            }
            // Waiting for the Kubernetes scheduler to bind the dummy pod.
            phase::PENDING => {
                let dummy = api.get(KIND_POD, &Self::dummy_pod_name(name))?;
                let bound = dummy.spec.opt_str("nodeName").is_some();
                if !bound {
                    return Ok(Reconcile::RequeueAfter(self.config.poll));
                }
                // Dummy pod placed: transfer the job through red-box
                // (qsub). The span parents on the job object's originating
                // trace — the WLM handoff is the tail of the create tree.
                let _span = crate::obs::span_with_parent(
                    "operator",
                    &format!("wlm-submit {name}"),
                    obj.meta
                        .annotation(crate::obs::TRACE_ANNOTATION)
                        .and_then(crate::obs::TraceContext::parse_wire),
                );
                let t_submit = std::time::Instant::now();
                let job_id = self.bridge.submit(&view.batch, "kube-operator")?;
                self.metrics
                    .observe("operator.submit_ns", t_submit.elapsed().as_nanos() as u64);
                self.tracked.lock().unwrap().insert(name.to_string(), job_id.clone());
                api.update_status(self.config.kind, name, &|o| {
                    o.status.insert("phase", phase::QUEUED);
                    o.status.insert("jobId", job_id.clone());
                })?;
                // The dummy pod's transfer duty is done.
                let _ = api.update_status(KIND_POD, &Self::dummy_pod_name(name), &|o| {
                    o.status.insert("phase", "Succeeded");
                    o.status.insert("log", format!("submitted as {job_id}"));
                });
                let _ = self.events.event(
                    api,
                    &obj,
                    EVENT_NORMAL,
                    "WlmSubmitted",
                    &format!(
                        "Submitted batch script to {} as job {job_id}",
                        self.config.wlm
                    ),
                );
                self.metrics.inc("operator.jobs_submitted");
                Ok(Reconcile::RequeueAfter(self.config.poll))
            }
            // Mirror WLM status until terminal.
            phase::QUEUED | phase::RUNNING => {
                let job_id = view
                    .wlm_job_id
                    .clone()
                    .ok_or_else(|| Error::internal("queued job without jobId"))?;
                let status = self.bridge.status(&job_id)?;
                let next = match status {
                    WlmStatus::Queued => phase::QUEUED,
                    WlmStatus::Running => phase::RUNNING,
                    WlmStatus::Completed => phase::TRANSFERRING,
                    WlmStatus::Failed { exit_code } => {
                        api.update_status(self.config.kind, name, &|o| {
                            o.status.insert("exitCode", exit_code as i64);
                        })?;
                        let _ = self.events.event(
                            api,
                            &obj,
                            EVENT_WARNING,
                            "WlmFailed",
                            &format!(
                                "{} job {job_id} failed with exit code {exit_code}",
                                self.config.wlm
                            ),
                        );
                        phase::FAILED
                    }
                    WlmStatus::Cancelled => phase::CANCELLED,
                    WlmStatus::Timeout => phase::TIMEOUT,
                };
                if next != view.status {
                    self.set_phase(api, name, next)?;
                }
                if phase::terminal(next) {
                    self.tracked.lock().unwrap().remove(name);
                    self.metrics.inc("operator.jobs_finished");
                    Ok(Reconcile::Ok)
                } else {
                    Ok(Reconcile::RequeueAfter(self.config.poll))
                }
            }
            // Job done on the WLM: stage results, then complete.
            phase::TRANSFERRING => {
                self.collect_results(api, &view)?;
                self.set_phase(api, name, phase::COMPLETED)?;
                self.tracked.lock().unwrap().remove(name);
                self.metrics.inc("operator.jobs_finished");
                Ok(Reconcile::Ok)
            }
            p if phase::terminal(p) => Ok(Reconcile::Ok),
            other => Err(Error::internal(format!("unknown operator phase `{other}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeRole, NodeSpec, SharedFs};
    use crate::kube::{KubeScheduler, KIND_TORQUEJOB};
    use crate::operator::redbox_svc::{RedboxBridge, TorqueLoginService};
    use crate::operator::virtual_node::register_virtual_nodes;
    use crate::pbs::{PbsConfig, PbsServer};
    use crate::redbox::{RedboxClient, RedboxServer};
    use crate::rt::{Shutdown, Timers};
    use crate::sched::EasyBackfill;
    use crate::singularity::{ImageRegistry, Runtime, RuntimeKind};
    use std::time::Instant;

    struct Env {
        api: ApiServer,
        sched: KubeScheduler,
        operator: Arc<WlmJobOperator>,
        pbs: PbsServer,
        _rb: RedboxServer,
        sd: Shutdown,
    }

    fn setup() -> Env {
        let sd = Shutdown::new();
        let (timers, _) = Timers::start(sd.clone());
        let runtime = Runtime::new(
            RuntimeKind::Singularity,
            ImageRegistry::with_defaults(),
            Metrics::new(),
        );
        let nodes = vec![
            NodeSpec::new("cn01", NodeRole::TorqueCompute, Resources::cores(8, 32 << 30)),
            NodeSpec::new("cn02", NodeRole::TorqueCompute, Resources::cores(8, 32 << 30)),
        ];
        let mut cfg = PbsConfig::default();
        cfg.time_scale = 0.001;
        cfg.sched_period = Duration::from_millis(2);
        let pbs = PbsServer::start(
            cfg,
            nodes,
            runtime,
            SharedFs::new(),
            Box::new(EasyBackfill),
            timers,
            Metrics::new(),
            sd.clone(),
        )
        .unwrap();
        let sock = std::env::temp_dir().join(format!(
            "hpcorc-opcore-{}-{:x}.sock",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .subsec_nanos()
        ));
        let mut rb = RedboxServer::start(&sock, sd.clone(), Metrics::new()).unwrap();
        rb.register("torque.Workload", TorqueLoginService::new(pbs.clone()));
        let bridge: Arc<dyn WlmBridge> =
            Arc::new(RedboxBridge::torque(RedboxClient::connect(&sock).unwrap()));
        let api = ApiServer::new(Metrics::new());
        register_virtual_nodes(&api, bridge.as_ref(), "torque").unwrap();
        let informers =
            crate::kube::SharedInformerFactory::new(api.client(), Metrics::new());
        let sched = KubeScheduler::new(&informers, Metrics::new());
        let operator = WlmJobOperator::new(OperatorConfig::torque(), bridge, Metrics::new());
        Env { api, sched, operator, pbs, _rb: rb, sd }
    }

    /// Drive scheduler + operator until the job object reaches a terminal
    /// phase (deterministic stepping, no daemon threads).
    fn drive(env: &Env, name: &str, timeout: Duration) -> String {
        let deadline = Instant::now() + timeout;
        loop {
            env.sched.run_cycle();
            let _ = env.operator.reconcile(&env.api, name);
            let obj = env.api.get(KIND_TORQUEJOB, name).unwrap();
            let p = obj.status.opt_str("phase").unwrap_or("").to_string();
            if phase::terminal(&p) {
                return p;
            }
            assert!(Instant::now() < deadline, "stuck in phase `{p}`");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    fn cow_job() -> crate::kube::KubeObject {
        WlmJobView::build_torquejob(
            "cow",
            "#!/bin/sh\n#PBS -l walltime=00:30:00\n#PBS -l nodes=1\n#PBS -e $HOME/low.err\n#PBS -o $HOME/low.out\nexport PATH=$PATH:/usr/local/bin\nsingularity run lolcow_latest.sif\n",
            "$HOME/low.out",
            "$HOME/results/",
        )
    }

    #[test]
    fn paper_fig3_to_fig5_flow() {
        let env = setup();
        env.api.create(cow_job()).unwrap();
        let final_phase = drive(&env, "cow", Duration::from_secs(20));
        assert_eq!(final_phase, phase::COMPLETED);

        // Fig. 2 artifacts: dummy pod landed on the virtual node, succeeded.
        let dummy = env.api.get(KIND_POD, "cow-submit").unwrap();
        assert_eq!(dummy.spec.opt_str("nodeName"), Some("vnode-torque-batch"));
        assert_eq!(dummy.status.opt_str("phase"), Some("Succeeded"));
        assert!(dummy.status.opt_str("log").unwrap().contains("torque-head"));

        // Fig. 5: results staged into the mount directory.
        let collected = env.pbs.fs().read_string("$HOME/results/low.out").unwrap();
        assert!(collected.contains("Moo"), "{collected}");
        let collect_pod = env.api.get(KIND_POD, "cow-collect").unwrap();
        assert_eq!(collect_pod.status.opt_str("phase"), Some("Succeeded"));

        // status.jobId recorded (qstat cross-check, paper §IV).
        let obj = env.api.get(KIND_TORQUEJOB, "cow").unwrap();
        let job_id = obj.status.opt_str("jobId").unwrap();
        let seq = crate::util::JobId::parse(job_id).unwrap().seq;
        assert_eq!(env.pbs.qstat_job(seq).unwrap().exit_code, Some(0));
        env.sd.trigger();
    }

    #[test]
    fn failed_wlm_job_reflected() {
        let env = setup();
        let obj = WlmJobView::build_torquejob("bad", "exit 3\n", "$HOME/x", "$HOME/");
        env.api.create(obj).unwrap();
        let p = drive(&env, "bad", Duration::from_secs(20));
        assert_eq!(p, phase::FAILED);
        let obj = env.api.get(KIND_TORQUEJOB, "bad").unwrap();
        assert_eq!(obj.status.opt_int("exitCode"), Some(3));
        // The operator narrates the WLM handoff through events.
        let events: Vec<crate::kube::EventView> = env
            .api
            .list(crate::kube::KIND_EVENT, &[])
            .iter()
            .map(|o| crate::kube::EventView::from_object(o).unwrap())
            .collect();
        let submitted = events.iter().find(|e| e.reason == "WlmSubmitted").unwrap();
        assert_eq!(submitted.regarding_name, "bad");
        assert_eq!(submitted.reporting_controller, COMPONENT);
        assert!(submitted.note.contains("torque"), "{}", submitted.note);
        let failed = events.iter().find(|e| e.reason == "WlmFailed").unwrap();
        assert_eq!(failed.etype, crate::kube::EVENT_WARNING);
        assert!(failed.note.contains("exit code 3"), "{}", failed.note);
        env.sd.trigger();
    }

    #[test]
    fn walltime_exceeded_is_timeout() {
        let env = setup();
        let obj = WlmJobView::build_torquejob(
            "slowpoke",
            "#PBS -l walltime=0:05\nsleep 60\n",
            "$HOME/x",
            "$HOME/",
        );
        env.api.create(obj).unwrap();
        let p = drive(&env, "slowpoke", Duration::from_secs(20));
        assert_eq!(p, phase::TIMEOUT);
        env.sd.trigger();
    }

    #[test]
    fn delete_cancels_wlm_job() {
        let env = setup();
        let obj = WlmJobView::build_torquejob("longrun", "sleep 600\n", "$HOME/x", "$HOME/");
        env.api.create(obj).unwrap();
        // Step until submitted.
        let deadline = Instant::now() + Duration::from_secs(10);
        let job_id = loop {
            env.sched.run_cycle();
            let _ = env.operator.reconcile(&env.api, "longrun");
            let o = env.api.get(KIND_TORQUEJOB, "longrun").unwrap();
            if let Some(id) = o.status.opt_str("jobId") {
                break id.to_string();
            }
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(2));
        };
        // Delete the CRD object (kubectl delete torquejob longrun).
        env.api.delete(KIND_TORQUEJOB, "longrun").unwrap();
        env.operator.reconcile(&env.api, "longrun").unwrap();
        // The PBS job must be cancelled.
        let seq = crate::util::JobId::parse(&job_id).unwrap().seq;
        let job = env.pbs.wait_for(seq, Duration::from_secs(10)).unwrap();
        assert!(job.cancelled);
        // Dummy pod cascade-deleted with the owner object.
        assert!(env.api.get(KIND_POD, "longrun-submit").is_err());
        env.sd.trigger();
    }

    #[test]
    fn job_without_results_spec_completes() {
        let env = setup();
        let mut obj = WlmJobView::build_torquejob("plain", "echo done\n", "", "");
        obj.spec.remove("results");
        obj.spec.remove("mount");
        env.api.create(obj).unwrap();
        let p = drive(&env, "plain", Duration::from_secs(20));
        assert_eq!(p, phase::COMPLETED);
        assert!(env.api.get(KIND_POD, "plain-collect").is_err(), "no results pod");
        env.sd.trigger();
    }

    #[test]
    fn queue_labelled_job_held_until_admitted() {
        let env = setup();
        let mut obj = cow_job();
        obj.meta.set_label(crate::kueue::QUEUE_NAME_LABEL, "tenant");
        env.api.create(obj).unwrap();
        for _ in 0..5 {
            env.sched.run_cycle();
            let _ = env.operator.reconcile(&env.api, "cow");
        }
        assert!(env.api.get(KIND_POD, "cow-submit").is_err(), "no dummy pod while gated");
        let o = env.api.get(KIND_TORQUEJOB, "cow").unwrap();
        assert_eq!(o.status.opt_str("phase").unwrap_or(""), "", "held suspended");
        // Admission flips the condition → the full Fig. 3 flow proceeds.
        env.api
            .update_status(KIND_TORQUEJOB, "cow", |o| {
                crate::kueue::set_condition(&mut o.status, crate::kueue::COND_ADMITTED, true);
            })
            .unwrap();
        let p = drive(&env, "cow", Duration::from_secs(20));
        assert_eq!(p, phase::COMPLETED);
        env.sd.trigger();
    }

    #[test]
    fn queue_extractors() {
        assert_eq!(
            torque_queue_extractor("#PBS -q gpu\necho x\n"),
            Some("gpu".to_string())
        );
        assert_eq!(torque_queue_extractor("echo x\n"), None);
        assert_eq!(
            slurm_queue_extractor("#SBATCH -p debug\necho x\n"),
            Some("debug".to_string())
        );
    }
}
