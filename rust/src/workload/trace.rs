//! Job trace format — the common input of the live replayer and the
//! discrete-event simulator (same workload, both paths).

use crate::encoding::{json, Value};
use crate::util::{Error, Result};

/// What the job's body does when run live.
#[derive(Debug, Clone, PartialEq)]
pub enum JobKind {
    /// Occupies resources for `runtime_s` (scaled) doing nothing.
    Sleep,
    /// Runs a compute artifact for `steps` (live path only; the sim uses
    /// `runtime_s` as its duration).
    Compute { artifact: String, steps: u32 },
}

/// One job of a trace. Times are nominal seconds from trace start.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceJob {
    pub id: u64,
    pub arrival_s: f64,
    pub nodes: u32,
    pub ppn: u32,
    /// Requested walltime (what the scheduler sees).
    pub walltime_s: f64,
    /// Actual runtime (what really happens; > walltime ⇒ killed).
    pub runtime_s: f64,
    pub priority: i64,
    pub queue: Option<String>,
    pub kind: JobKind,
}

impl TraceJob {
    pub fn sleep(id: u64, arrival_s: f64, nodes: u32, ppn: u32, walltime_s: f64, runtime_s: f64) -> Self {
        TraceJob {
            id,
            arrival_s,
            nodes,
            ppn,
            walltime_s,
            runtime_s,
            priority: 0,
            queue: None,
            kind: JobKind::Sleep,
        }
    }

    /// Render as a PBS script for the live path.
    pub fn to_pbs_script(&self, time_scale_hint: f64) -> String {
        let _ = time_scale_hint;
        let wall = crate::util::fmt_walltime(std::time::Duration::from_secs_f64(
            self.walltime_s.max(1.0),
        ));
        let mut s = format!(
            "#!/bin/sh\n#PBS -N trace-{}\n#PBS -l walltime={wall}\n#PBS -l nodes={}:ppn={}\n",
            self.id, self.nodes, self.ppn
        );
        if let Some(q) = &self.queue {
            s.push_str(&format!("#PBS -q {q}\n"));
        }
        if self.priority != 0 {
            s.push_str(&format!("#PBS -p {}\n", self.priority));
        }
        match &self.kind {
            JobKind::Sleep => s.push_str(&format!("sleep {}\n", self.runtime_s)),
            JobKind::Compute { artifact, steps } => {
                s.push_str(&format!("singularity run {artifact}_{steps}.sif\n"))
            }
        }
        s
    }
}

/// A full trace.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Trace {
    pub name: String,
    pub jobs: Vec<TraceJob>,
}

impl Trace {
    pub fn new(name: impl Into<String>, mut jobs: Vec<TraceJob>) -> Trace {
        jobs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        Trace { name: name.into(), jobs }
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Total core-seconds demanded (for utilization bounds).
    pub fn core_seconds(&self) -> f64 {
        self.jobs
            .iter()
            .map(|j| (j.nodes * j.ppn) as f64 * j.runtime_s.min(j.walltime_s))
            .sum()
    }

    pub fn to_json(&self) -> String {
        let jobs: Vec<Value> = self
            .jobs
            .iter()
            .map(|j| {
                let mut v = Value::map()
                    .with("id", j.id)
                    .with("arrival", j.arrival_s)
                    .with("nodes", j.nodes as u64)
                    .with("ppn", j.ppn as u64)
                    .with("walltime", j.walltime_s)
                    .with("runtime", j.runtime_s)
                    .with("priority", j.priority);
                if let Some(q) = &j.queue {
                    v.insert("queue", q.clone());
                }
                match &j.kind {
                    JobKind::Sleep => v.insert("kind", "sleep"),
                    JobKind::Compute { artifact, steps } => {
                        v.insert("kind", "compute");
                        v.insert("artifact", artifact.clone());
                        v.insert("steps", *steps as u64);
                    }
                }
                v
            })
            .collect();
        json::to_string_pretty(
            &Value::map().with("name", self.name.clone()).with("jobs", Value::Seq(jobs)),
        )
    }

    pub fn from_json(text: &str) -> Result<Trace> {
        let v = json::parse(text)?;
        let jobs = v
            .req("jobs")?
            .as_seq()
            .ok_or_else(|| Error::parse("jobs must be a list"))?
            .iter()
            .map(|j| -> Result<TraceJob> {
                let kind = match j.opt_str("kind").unwrap_or("sleep") {
                    "compute" => JobKind::Compute {
                        artifact: j.req_str("artifact")?.to_string(),
                        steps: j.opt_int("steps").unwrap_or(1) as u32,
                    },
                    _ => JobKind::Sleep,
                };
                Ok(TraceJob {
                    id: j.req_int("id")? as u64,
                    arrival_s: j.req("arrival")?.as_f64().unwrap_or(0.0),
                    nodes: j.opt_int("nodes").unwrap_or(1) as u32,
                    ppn: j.opt_int("ppn").unwrap_or(1) as u32,
                    walltime_s: j.req("walltime")?.as_f64().unwrap_or(60.0),
                    runtime_s: j.req("runtime")?.as_f64().unwrap_or(60.0),
                    priority: j.opt_int("priority").unwrap_or(0),
                    queue: j.opt_str("queue").map(String::from),
                    kind,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Trace::new(v.opt_str("name").unwrap_or("trace"), jobs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let trace = Trace::new(
            "t",
            vec![
                TraceJob::sleep(1, 0.0, 1, 2, 100.0, 80.0),
                TraceJob {
                    id: 2,
                    arrival_s: 5.0,
                    nodes: 2,
                    ppn: 4,
                    walltime_s: 600.0,
                    runtime_s: 300.0,
                    priority: 3,
                    queue: Some("batch".into()),
                    kind: JobKind::Compute { artifact: "cropyield_train_tiny".into(), steps: 50 },
                },
            ],
        );
        let back = Trace::from_json(&trace.to_json()).unwrap();
        assert_eq!(back, trace);
    }

    #[test]
    fn jobs_sorted_by_arrival() {
        let trace = Trace::new(
            "t",
            vec![TraceJob::sleep(1, 9.0, 1, 1, 10.0, 10.0), TraceJob::sleep(2, 1.0, 1, 1, 10.0, 10.0)],
        );
        assert_eq!(trace.jobs[0].id, 2);
    }

    #[test]
    fn pbs_script_render() {
        let j = TraceJob::sleep(7, 0.0, 2, 4, 90.0, 60.0);
        let s = j.to_pbs_script(1.0);
        assert!(s.contains("#PBS -l nodes=2:ppn=4"));
        assert!(s.contains("#PBS -l walltime=00:01:30"));
        assert!(s.contains("sleep 60"));
        let parsed = crate::pbs::PbsScript::parse(&s).unwrap();
        assert_eq!(parsed.nodes, 2);
    }

    #[test]
    fn core_seconds() {
        let trace = Trace::new("t", vec![TraceJob::sleep(1, 0.0, 2, 4, 100.0, 50.0)]);
        assert_eq!(trace.core_seconds(), 8.0 * 50.0);
    }
}
