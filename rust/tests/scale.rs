//! 100k-object scale correctness (PR 6). Gated behind `--ignored`: these
//! are minutes-of-CPU tests, run explicitly (the latency numbers live in
//! `benches/store_scale.rs`; this file checks the *answers* stay right at
//! scale, not how fast they arrive).
//!
//!     cargo test --release --test scale -- --ignored
//!
//! Object count defaults to 100_000; override with STORE_SCALE_N.

use hpcorc::cluster::{Metrics, Resources};
use hpcorc::kube::{
    ApiServer, KubeObject, KubeScheduler, ListOptions, NodeView, PodView, SharedInformerFactory,
    WalBackend, KIND_POD,
};

fn n_objects() -> usize {
    std::env::var("STORE_SCALE_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100_000)
}

fn pod(i: usize) -> KubeObject {
    PodView::build(&format!("pod-{i:06}"), "img.sif", Resources::new(100, 1 << 20, 0), &[])
}

fn seeded(n: usize) -> ApiServer {
    let api = ApiServer::new(Metrics::new());
    for i in 0..n {
        api.create(pod(i)).unwrap();
    }
    api
}

/// A paged informer seed over 100k objects caches every one of them, and
/// the live watch still works after (the seed didn't wedge the history).
#[test]
#[ignore = "100k-object scale harness: cargo test --release --test scale -- --ignored"]
fn informer_seeds_every_object_at_scale() {
    let n = n_objects();
    let api = seeded(n);
    let informers = SharedInformerFactory::new(api.client(), Metrics::new());
    let pods = informers.informer(KIND_POD);
    pods.sync().unwrap();
    assert_eq!(pods.len(), n, "paged seed must cache all {n} objects");
    assert!(pods.get(&format!("pod-{:06}", n - 1)).is_some());
    api.create(pod(n)).unwrap();
    pods.sync().unwrap();
    assert_eq!(pods.len(), n + 1, "live tail works after the paged seed");
}

/// Delta lists stay exact at scale: after k changes among 100k objects,
/// a delta relist ships exactly the k changed objects (plus deletions by
/// name), at the store's current resource version.
#[test]
#[ignore = "100k-object scale harness: cargo test --release --test scale -- --ignored"]
fn delta_list_is_exact_at_scale() {
    let n = n_objects();
    let api = seeded(n);
    let floor = api.current_version();
    let k = 512.min(n / 2);
    for i in 0..k {
        api.update_status(KIND_POD, &format!("pod-{i:06}"), |o| {
            o.status.insert("phase", "Running");
        })
        .unwrap();
    }
    api.delete(KIND_POD, &format!("pod-{:06}", n - 1)).unwrap();

    let l = api.list_opts(KIND_POD, &ListOptions::all().delta_since(floor)).unwrap();
    assert!(l.delta, "fresh floor must take the delta path");
    assert_eq!(l.items.len(), k, "exactly the changed objects ship");
    assert_eq!(l.deleted, vec![format!("pod-{:06}", n - 1)]);
    assert_eq!(l.resource_version, api.current_version());
    for (i, o) in l.items.iter().enumerate() {
        assert_eq!(o.meta.name, format!("pod-{i:06}"), "coalesced by name, in order");
        assert_eq!(o.status.opt_str("phase"), Some("Running"));
    }
}

/// Flash crowd against a 10k-node fleet (PR 9): the indexed scheduler
/// drains the whole burst, every pod lands on a real node, and
/// steady-state cycles afterwards issue ZERO list RPCs — the index and
/// the informer caches absorb everything. Node count defaults to 10_000
/// (override with SCHED_SCALE_NODES), burst to 512 (SCHED_SCALE_PODS).
#[test]
#[ignore = "10k-node scale harness: cargo test --release --test scale -- --ignored"]
fn flash_crowd_drains_at_scale_with_zero_steady_state_lists() {
    let nodes: usize =
        std::env::var("SCHED_SCALE_NODES").ok().and_then(|v| v.parse().ok()).unwrap_or(10_000);
    let burst: usize =
        std::env::var("SCHED_SCALE_PODS").ok().and_then(|v| v.parse().ok()).unwrap_or(512);
    let api_metrics = Metrics::new();
    let api = ApiServer::new(api_metrics.clone());
    for i in 0..nodes {
        api.create(NodeView::build(&format!("w{i:05}"), Resources::cores(64, 256 << 30), &[]))
            .unwrap();
    }
    let informers = SharedInformerFactory::new(api.client(), Metrics::new());
    let sched = KubeScheduler::new(&informers, Metrics::new());
    assert_eq!(sched.run_cycle(), 0, "seed cycle: empty fleet, nothing pending");

    for i in 0..burst {
        api.create(pod(i)).unwrap();
    }
    let mut bound = 0;
    for _ in 0..10 {
        bound += sched.run_cycle();
        if bound == burst {
            break;
        }
    }
    assert_eq!(bound, burst, "the whole flash crowd must drain");
    for i in (0..burst).step_by((burst / 8).max(1)) {
        let node = api
            .get(KIND_POD, &format!("pod-{i:06}"))
            .unwrap()
            .spec
            .opt_str("nodeName")
            .map(String::from);
        assert!(node.is_some_and(|n| n.starts_with('w')), "pod-{i:06} must be bound");
    }

    // Steady state: 25 cycles with nothing to do issue zero list RPCs —
    // reads come from the caches, index maintenance from watch deltas.
    let lists_before = api_metrics.counter_value("kube.api.list");
    for _ in 0..25 {
        assert_eq!(sched.run_cycle(), 0);
    }
    assert_eq!(
        api_metrics.counter_value("kube.api.list"),
        lists_before,
        "steady-state scheduling cycles must issue ZERO list RPCs"
    );
}

/// WAL replay at scale: 100k durable creations reopen to the same object
/// count, the same version counter, and spot-checked identical objects.
#[test]
#[ignore = "100k-object scale harness: cargo test --release --test scale -- --ignored"]
fn wal_replay_recovers_at_scale() {
    let n = n_objects();
    let dir = std::env::temp_dir().join(format!("hpcorc-scale-wal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let first = ApiServer::with_backend(
        Metrics::new(),
        // Threshold past n: pure-WAL replay. (Compacted recovery is
        // covered at small scale in tests/persist.rs.)
        Box::new(WalBackend::open(&dir).unwrap().with_compact_threshold(n * 2)),
        4096,
    )
    .unwrap();
    for i in 0..n {
        first.create(pod(i)).unwrap();
    }
    let version = first.current_version();
    let sample: Vec<KubeObject> = [0, n / 2, n - 1]
        .iter()
        .map(|&i| first.get(KIND_POD, &format!("pod-{i:06}")).unwrap())
        .collect();
    drop(first);

    let second = ApiServer::with_backend(
        Metrics::new(),
        Box::new(WalBackend::open(&dir).unwrap().with_compact_threshold(n * 2)),
        4096,
    )
    .unwrap();
    assert_eq!(second.current_version(), version);
    assert_eq!(second.list(KIND_POD, &[]).len(), n);
    for want in &sample {
        let got = second.get(KIND_POD, &want.meta.name).unwrap();
        assert_eq!(&got, want, "replayed object must be byte-identical");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
