//! Kubernetes-like orchestrator: the big-data cluster of the paper's
//! testbed (Fig. 1). Dynamic object model with CRDs ([`api`]), versioned
//! store with watches ([`store`]), API server with an RPC surface
//! ([`apiserver`]), the scheduler ([`scheduler`]), the node agent
//! ([`kubelet`]), the controller runtime ([`controller`]), a Deployment
//! controller ([`deployment`]), and manifest handling ([`yaml`]).
//!
//! # The API layer: Scheme, ApiClient, `Api<K>`
//!
//! Three pieces make the resource API uniform across kinds and transports:
//!
//! - **[`Scheme`]** ([`scheme`]) is the kind registry: every kind — built-in
//!   or CRD — registers its [`GroupVersionKind`], plural, and short names.
//!   [`default_scheme`] ships Pod/Node/Deployment plus the paper's
//!   `TorqueJob`/`SlurmJob` CRDs under `wlm.sylabs.io/v1alpha1`; the CLI
//!   resolves `kubectl get tj` through it instead of hardcoded aliases.
//! - **[`ApiClient`]** ([`client`]) is the transport trait: the full verb
//!   set (`create`/`get`/`update`/`update_status`/`patch_merge`/`delete`/
//!   `apply`/`list` with [`ListOptions`]/`watch`). The in-process
//!   [`ApiServer`] and the socket-backed [`RemoteApi`] both implement it
//!   with identical semantics (see `tests/api_parity.rs`), so controllers
//!   hold `Arc<dyn ApiClient>` and never care which side of the red-box
//!   socket they run on. The remote watch is **server-push** (ISSUE 5):
//!   `kube.Api/Watch stream:true` rides red-box's multiplexed frame
//!   layer, the server pushes events (+ periodic `BOOKMARK` frames, + a
//!   `gone` StreamEnd for stale bookmarks — the 410 signal), and an idle
//!   watch transmits nothing. Fallback negotiation is automatic: a server
//!   that answers the poll shape (or [`WatchConfig::force_poll`]) drops
//!   the client into the legacy poll loop with configurable cadences;
//!   [`RemoteApi::last_watch_mode`] reports which mode a watch got.
//!   Stream loss surfaces identically in both modes (ended receiver →
//!   relist + rewatch), so consumers never know the difference.
//! - **[`Api<K>`]** is the typed handle: `Api::<PodView>::new(client)`
//!   returns [`PodView`]s instead of raw [`KubeObject`] trees, the kube-rs
//!   shape. Views implement [`ResourceView`]; a view family covering
//!   several kinds (e.g. [`WlmJobView`] for TorqueJob + SlurmJob) picks a
//!   member with `Api::of_kind`.
//!
//! ## Registering a new CRD kind
//!
//! 1. Register the kind in a scheme so tooling resolves its aliases:
//!    `scheme.register_wlm_crd("FlinkJob", "flinkjobs", &["fj"])` (or
//!    [`Scheme::register`] with a custom [`GroupVersionKind`]).
//! 2. Define a typed view implementing [`ResourceView`] (decode
//!    spec/status into a struct; see [`WlmJobView`]).
//! 3. Write a [`Controller`] for the kind and run it with
//!    [`ControllerRunner`] — the store serves unknown kinds natively, so
//!    no server-side change is needed (paper §III-B: the operator
//!    "introduces a new object kind" through the same machinery).
//!
//! # The informer layer: read the cache, never re-list (PR 4)
//!
//! Control loops do **not** call `list()` per cycle. A per-kind
//! reflector ([`informer`]) seeds a local cache with one paged list,
//! then tails `watch()` events into it; every consumer in the process
//! shares that cache through a [`SharedInformerFactory`]. Steady-state
//! reconcile cycles therefore issue *zero* full-list RPCs (proven by
//! `tests/informer.rs` with a counting client) — the O(cluster) cost
//! moves to one seed and to explicitly-signalled resyncs.
//!
//! The how-to for a new control loop:
//!
//! 1. Take a `&SharedInformerFactory` in your constructor and keep the
//!    [`Informer`] handles you need: `factory.informer(KIND_POD)`. Keep
//!    `factory.client()` for writes — informers are the read path only.
//! 2. At the top of each cycle call [`Informer::sync`] (drains pending
//!    watch events; cheap when the factory pump thread is running), then
//!    read: [`Informer::get`]/[`Informer::list`], the indexed
//!    [`Informer::list_labelled`] / [`Informer::list_by_field`] (register
//!    the path once with [`Informer::ensure_field_index`]) /
//!    [`Informer::list_owned_by`], or the zero-copy [`Informer::read`]
//!    scan for hot paths.
//! 3. For event-driven wake-ups, [`Informer::subscribe`] (or
//!    `subscribe_with` to multiplex kinds into one channel). The current
//!    cache replays as `Applied` events, then deltas stream live.
//! 4. Handle [`InformerEvent::Resync`]: the reflector lost its watch
//!    stream (remote restart, or the bookmark fell out of the store's
//!    retained history window — the 410-Gone signal), relisted, and
//!    bumped its epoch. Any state you derived from individual events
//!    (ledgers, known-name sets) must rebuild from the cache, because
//!    events may have been lost in the gap. [`ControllerRunner`] and
//!    `kueue::AdmissionCore` are the reference implementations.
//!
//! Daemons: `factory.start(period, shutdown)` runs the pump thread that
//! drains watch streams and pushes events to subscribers; tests instead
//! step `create → sync → read` deterministically. Size the server's
//! watch-history window ([`ApiServer::with_history_cap`]) above the
//! largest expected write burst, or reflectors are forced into spurious
//! relists.
//!
//! Remote informers are push-fed: over a streaming [`RemoteApi`] watch,
//! an **idle informer performs zero RPC round-trips** (proven in
//! `tests/informer.rs`) — the last per-cycle polling hot path is gone.
//!
//! # Persistence layer (PR 6)
//!
//! The store is sharded per kind and commits through a pluggable
//! durability boundary ([`persist`]):
//!
//! - **Backend trait.** Every mutation is handed to a
//!   [`persist::StoreBackend`] *before* it becomes visible
//!   (append-on-commit); a failed append aborts the commit. The default
//!   [`persist::MemoryBackend`] is a no-op; [`persist::WalBackend`]
//!   writes one JSON line per commit to `<dir>/wal.log` and compacts the
//!   full object set into `<dir>/snapshot.json` (temp-file + rename,
//!   crash-safe) every `DEFAULT_COMPACT_THRESHOLD` appends. Build a
//!   durable server with [`ApiServer::with_backend`] (CLI:
//!   `hpcorc up --wal-dir DIR`); reopening the same directory recovers
//!   every object, the resource-version/uid counters, and the store
//!   clock — `kubectl get` output is byte-identical across the restart.
//! - **Shard/version contract.** `resourceVersion`s come from one global
//!   counter (writes serialize through a global commit lock, like etcd's
//!   single log), but objects, watch histories, and watcher lists live
//!   in per-kind shards with independent locks: pod churn cannot stall a
//!   node/queue read, and cannot trim another kind's watch history. A
//!   per-kind watch from bookmark `b` replays exactly that kind's events
//!   in `(b, now]` or reports 410-Gone against *its own* retained
//!   window; cross-kind churn surfaces only as BOOKMARK frames (PR 5),
//!   whose semantics are unchanged. See `Store::shard_version` and the
//!   shard-contract tests in `tests/api_parity.rs`.
//! - **Delta relists.** [`ListOptions::delta_since`] asks a list to ship
//!   only what changed after a version: the server answers from the
//!   shard history with changed objects + deleted names
//!   ([`ObjectList::delta`] = true) when the window still covers the
//!   bookmark, or falls back to a full list. The [`informer`] reflector
//!   uses it on 410-Gone/stream-loss recovery — a resync of a huge kind
//!   ships a handful of events, keeps the cache epoch, and emits **no**
//!   `Resync` (derived ledgers stay incremental). Because a recovered
//!   [`persist::WalBackend`] seeds shard histories from the WAL tail,
//!   this works *across server restarts* too.
//!
//! Scale: `benches/store_scale.rs` + the `#[ignore]`d `tests/scale.rs`
//! stand up 100k objects and track create/list/watch-fanout p99 plus the
//! pod-churn-vs-node-read isolation ratio in the CI perf trajectory.
//!
//! # Observability layer (PR 7)
//!
//! Everything in this module is causally traceable end to end — see
//! [`crate::obs`] for the span recorder, the metric-name catalog, and
//! the remote `obs.Metrics`/`obs.Spans` services. The how-to for
//! instrumenting a new control loop:
//!
//! 1. **Join the object's trace, don't start your own.** A write path
//!    stamps its active span onto created objects as the
//!    `hpcorc.io/trace` annotation ([`crate::obs::TRACE_ANNOTATION`],
//!    done centrally by [`ApiServer::create`]/`apply`). A control loop
//!    reacting to that object later opens its span with
//!    [`crate::obs::span_with_parent`], passing
//!    `obj.meta.annotation(TRACE_ANNOTATION)` parsed through
//!    [`crate::obs::TraceContext::parse_wire`] — the scheduler's bind,
//!    kueue's admit, and the operator's WLM submit are the reference
//!    call sites. Writes made while the span guard is alive propagate
//!    the context automatically (the red-box client stamps `current()`
//!    onto every outgoing request; the in-process [`ApiServer`] reads
//!    the same thread-local).
//! 2. **Name latency histograms `<component>.<what>_ns`** and observe
//!    them with `metrics.observe(...)` — they render as Prometheus
//!    histograms (cumulative `_bucket`/`_sum`/`_count`) on the
//!    `obs.Metrics/Prom` scrape and as p50/p95/p99 summaries in the
//!    JSON snapshot. The store commit path (`kube.store.commit_ns`,
//!    `wal_append_ns`, `fanout_ns`), informer delivery
//!    (`kube.informer.deliver_ns`), and the end-to-end
//!    `slo.pod_create_to_bound_ns` SLO are the shipped examples.
//! 3. **Inspect from outside**: `hpcorc metrics --socket S [--prom|--json]`
//!    scrapes a live daemon; `hpcorc trace KIND/NAME --socket S`
//!    reconstructs an object's lifecycle timeline (`--json` dumps
//!    Chrome trace events for Perfetto). `tests/obs_e2e.rs` is the
//!    acceptance: one pod's create→admit→schedule→bind is one connected
//!    trace, and the SLO histogram is remotely scrapeable.
//!
//! # Events & audit (PR 8)
//!
//! Two human-facing records of what the cluster did, layered on the
//! machinery above:
//!
//! - **Cluster Events** ([`events`]): `Event` is a real API object
//!   (`events.k8s.io/v1` shape, registered in [`default_scheme`] as
//!   `events`/`ev`), so it rides the store/WAL/watch machinery
//!   unchanged. Components emit through a per-component
//!   [`EventRecorder`] — `rec.event(&api, &pod, EVENT_NORMAL,
//!   "Scheduled", "bound to w1")` — which coalesces repeats of the same
//!   `(object, reason)` within a window into a `status.count` bump
//!   (the k8s events-spam defence) and carries the regarding object's
//!   `hpcorc.io/trace` annotation onto the event. TTL GC
//!   ([`events::gc_expired`]) reaps stale events; the testbed ticks it.
//!   Read side: `kubectl get events` (LAST SEEN/COUNT columns, sorted)
//!   and `kubectl describe KIND/NAME` (object + its events + the causal
//!   span timeline of its trace).
//!
//!   The shipped emitters: the scheduler (`Scheduled`/
//!   `FailedScheduling` with the losing predicate), kueue (`Admitted`/
//!   `Evicted`/`QuotaExhausted` with the cohort math), the kubelet
//!   (`Started`/`Killing`/`Reaped`), the operator (`WlmSubmitted`/
//!   `WlmFailed` with backend + job id), and the autoscalers
//!   (`ScaledUp`/`ScaledDown`/`Provisioned`/`BurstToWlm`).
//!
//! - **API audit trail** ([`crate::obs::AuditLog`]): every mutating
//!   ApiServer verb appends verb/kind/name/**actor**/trace/outcome/
//!   latency to a bounded ring inside the server, with an optional file
//!   sink (`hpcorc up --audit-log FILE`). Actor attribution rides a
//!   thread-local ([`crate::obs::push_actor`]) that components pin per
//!   cycle and the red-box transport carries as the request's `actor`
//!   field — so `hpcorc audit [--since SEQ] [--kind KIND]` shows a
//!   remote `kubectl apply` and an in-process scheduler bind through
//!   one code path, each tied to its originating trace id.
//!
//! # Scheduler layer (PR 9): the fit/score index and batched binds
//!
//! [`KubeScheduler`] no longer scans the fleet per pod. A scheduling
//! cycle consults a [`SchedIndex`] — an incrementally-maintained
//! structure fed by the node/pod informer subscriptions — and commits
//! all of a cycle's placements through one batched write. The pieces:
//!
//! - **Index invariants** ([`sched_index`]): nodes are bucketed by
//!   taint/label *signature* (sorted, deduped), and each bucket orders
//!   its nodes by dominant-fraction fullness (ties by name). Only
//!   `Ready && !unschedulable` nodes live in buckets; the excluded ones
//!   are counted (`not_ready`/`cordoned`) so unschedulable verdicts
//!   still reproduce the exact `0/N nodes available: ...` message of
//!   the old full walk — byte-identical, regression-tested. Candidate
//!   selection walks only buckets whose signature the pod
//!   tolerates/selects, ascending by fullness, and stops a bucket as
//!   soon as its emptiest node is already fuller than the best score
//!   found — correct because a node's post-placement score is never
//!   below its current fullness (dominant fraction is monotone). The
//!   result provably equals the brute-force argmin (differential test
//!   in `sched_index.rs`, plus `run_cycle_brute` as a live oracle).
//! - **Reserve/confirm lifecycle**: node usage is `confirmed ⊕
//!   reserved`. Confirmed usage comes from the informer echo (pods with
//!   a bound node); a placement *reserves* capacity synchronously the
//!   moment the cycle picks a node, so the next cycle never
//!   double-places against unconfirmed capacity while the bind is in
//!   flight. The informer echo of the bound pod consumes the
//!   reservation (a Pending echo does not); a failed bind un-reserves,
//!   and the still-Pending pod simply requeues on a later cycle. On
//!   [`InformerEvent::Resync`] the index rebuilds from the caches to
//!   the fresh-start fixed point, re-applying only reservations not yet
//!   confirmed.
//! - **Batch semantics**: binds ship as [`BatchPatchItem`]s through
//!   [`ApiClient::update_status_batch`] — ONE red-box round trip for N
//!   binds. The in-process [`ApiServer`] applies the whole batch inside
//!   a single store lock section (`Store::update_batch`), so there is
//!   no conflict window at all; results are positional and per item
//!   (one NotFound never poisons its batch-mates), and each item still
//!   writes its own `update_status` audit record. Daemon mode
//!   ([`KubeScheduler::start`]) hands batches to a background committer
//!   thread; single-shot `run_cycle()` commits inline. Per-bind spans
//!   still parent on the pod's originating trace, so `hpcorc trace`
//!   shows the batched bind exactly like a single one.
//!
//! Throughput: `benches/scheduler.rs` tracks pods-scheduled-per-second
//! at 1k/10k nodes (indexed vs brute ≥ 10×), index-maintenance cost per
//! delta, and batched-vs-single bind round trips; `tests/scale.rs` has
//! the gated 10k-node flash-crowd drain.
//!
//! # Disruption API (PR 10): eviction + PodDisruptionBudgets
//!
//! All *voluntary* disruptions — kueue preemption, cluster-autoscaler
//! drain, chaos kubelet-kill — go through one typed subresource instead
//! of ad-hoc `delete`/`update_status` calls:
//!
//! - **[`ApiClient::evict`]** is the `pods/eviction` verb. It takes an
//!   [`EvictionMode`]: `Delete` removes the pod (CA drain), `Requeue {
//!   gate }` atomically unbinds the pod, resets it to `Pending`, and
//!   re-adds the scheduling gate *in one server-side write* (kueue
//!   preemption — the scheduler can never re-bind a half-evicted pod).
//!   The typed handle is `Api::<PodView>::evict`; any other `Api<K>`
//!   refuses — eviction is a pods subresource.
//! - **[`PdbView`]** (`policy/v1 PodDisruptionBudget`, `kubectl get
//!   pdb`) guards it. The server checks every budget whose selector
//!   matches the victim: `minAvailable` blocks when healthy (Running)
//!   matching pods would drop below the floor, `maxUnavailable` when
//!   unavailability would exceed the ceiling. A refusal is the typed
//!   [`crate::util::ApiError::DisruptionBudgetExceeded`] — it crosses
//!   the red-box wire intact (parity-tested), so remote drain loops
//!   branch on `err.is_disruption_budget_exceeded()` and retry later,
//!   exactly like in-process ones. Every eviction attempt (allowed or
//!   blocked) is an `evict` audit record and refreshes
//!   `status.disruptionsAllowed` on the covering budgets.
//!
//! # CRDs served through the API (PR 10)
//!
//! `CustomResourceDefinition` (`apiextensions.k8s.io/v1`, `kubectl get
//! crd`) is itself an API object: `create`/`apply` of a CRD extends the
//! *server's* kind registry at runtime. The server owns a
//! [`SchemeRegistry`] (a shared, mutable [`Scheme`]) instead of the
//! process-static [`default_scheme`]; a registered kind's plural/short
//! names resolve server-side, so `kubectl get <alias>` works over the
//! socket with zero CLI changes, and metric/audit labels pick up the
//! registered plural. Re-`apply` of an identical CRD is idempotent;
//! a conflicting redefinition is `Invalid`. WAL recovery replays stored
//! CRDs back into the fresh registry before serving, so dynamic kinds
//! survive a restart like everything else.

pub mod api;
pub mod apiserver;
pub mod client;
pub mod controller;
pub mod deployment;
pub mod events;
pub mod informer;
pub mod kubelet;
pub mod persist;
pub mod sched_index;
pub mod scheduler;
pub mod scheme;
pub mod store;
pub mod yaml;

pub use api::{
    add_scheduling_gate, pdb_blocking, pdb_disruptions_allowed, remove_scheduling_gate,
    scheduling_gates, CrdView, KubeObject, NodeView, ObjectMeta, PdbView, PodPhase, PodView,
    WlmJobView, APIEXTENSIONS_API_VERSION, KIND_CUSTOMRESOURCEDEFINITION, KIND_DEPLOYMENT,
    KIND_NODE, KIND_POD, KIND_PODDISRUPTIONBUDGET, KIND_SLURMJOB, KIND_TORQUEJOB,
    POLICY_API_VERSION, WLM_API_VERSION,
};
pub use apiserver::{
    ApiServer, MutatingHook, RemoteApi, WatchConfig, WatchMode, MAX_CONFLICT_RETRIES,
};
pub use client::{
    ActorClient, Api, ApiClient, BatchPatchItem, EvictionMode, ListOptions, ObjectList,
    ResourceView,
};
pub use controller::{Controller, ControllerRunner, Reconcile};
pub use deployment::DeploymentController;
pub use events::{
    gc_expired, EventRecorder, EventView, DEFAULT_COALESCE_WINDOW_S, EVENTS_API_VERSION,
    EVENT_NORMAL, EVENT_WARNING, KIND_EVENT,
};
pub use informer::{Informer, InformerEvent, SharedInformerFactory};
pub use kubelet::Kubelet;
pub use persist::{MemoryBackend, StoreBackend, WalBackend};
pub use sched_index::{Eliminations, SchedIndex};
pub use scheduler::KubeScheduler;
pub use scheme::{default_scheme, GroupVersionKind, KindSpec, Scheme, SchemeRegistry};
pub use store::{Store, WatchEvent, DEFAULT_HISTORY_CAP};
