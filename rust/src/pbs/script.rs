//! PBS batch script parser: the `#PBS` directive dialect of the paper's
//! Fig. 3 plus the directives Torque users rely on day-to-day.
//!
//! ```text
//! #!/bin/sh
//! #PBS -N cow                      job name
//! #PBS -q batch                    destination queue
//! #PBS -l walltime=00:30:00       resource list (walltime, nodes, ppn, mem)
//! #PBS -l nodes=1:ppn=2
//! #PBS -e $HOME/low.err            stderr path
//! #PBS -o $HOME/low.out            stdout path
//! #PBS -p 10                       priority
//! #PBS -v A=1,B=2                  exported environment
//! <body: shell lines>
//! ```

use crate::util::{parse_mem, parse_walltime, Error, Result};
use std::time::Duration;

/// Parsed PBS script.
#[derive(Debug, Clone, PartialEq)]
pub struct PbsScript {
    pub name: Option<String>,
    pub queue: Option<String>,
    pub nodes: u32,
    pub ppn: u32,
    /// Per-chunk memory request (`-l mem=`), bytes.
    pub mem: u64,
    pub walltime: Duration,
    pub priority: i64,
    pub stdout_path: Option<String>,
    pub stderr_path: Option<String>,
    pub env: Vec<(String, String)>,
    /// Node properties required (`-l nodes=1:ppn=2:bigmem` → ["bigmem"]).
    pub properties: Vec<String>,
    /// The executable body (shell lines, shebang/comments included).
    pub body: Vec<String>,
}

impl Default for PbsScript {
    fn default() -> Self {
        PbsScript {
            name: None,
            queue: None,
            nodes: 1,
            ppn: 1,
            mem: 0,
            walltime: Duration::from_secs(3600), // Torque default 1h
            priority: 0,
            stdout_path: None,
            stderr_path: None,
            env: Vec::new(),
            properties: Vec::new(),
            body: Vec::new(),
        }
    }
}

impl PbsScript {
    /// Parse a full script text.
    pub fn parse(text: &str) -> Result<PbsScript> {
        let mut script = PbsScript::default();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim_end();
            if let Some(directive) = line.trim_start().strip_prefix("#PBS") {
                script.apply_directive(directive.trim()).map_err(|e| {
                    Error::parse(format!("line {}: {e}", lineno + 1))
                })?;
            } else {
                script.body.push(line.to_string());
            }
        }
        // Trim leading/trailing blank body lines (directives removed).
        while script.body.first().map(|l| l.trim().is_empty()) == Some(true) {
            script.body.remove(0);
        }
        while script.body.last().map(|l| l.trim().is_empty()) == Some(true) {
            script.body.pop();
        }
        Ok(script)
    }

    fn apply_directive(&mut self, directive: &str) -> Result<()> {
        let (flag, rest) = directive
            .split_once(char::is_whitespace)
            .map(|(f, r)| (f, r.trim()))
            .unwrap_or((directive, ""));
        match flag {
            "-N" => self.name = Some(nonempty(rest, "-N")?.to_string()),
            "-q" => self.queue = Some(nonempty(rest, "-q")?.to_string()),
            "-o" => self.stdout_path = Some(nonempty(rest, "-o")?.to_string()),
            "-e" => self.stderr_path = Some(nonempty(rest, "-e")?.to_string()),
            "-p" => {
                self.priority = rest
                    .parse()
                    .map_err(|_| Error::parse(format!("bad priority `{rest}`")))?
            }
            "-l" => self.apply_resource_list(rest)?,
            "-v" => {
                for pair in rest.split(',') {
                    if let Some((k, v)) = pair.split_once('=') {
                        self.env.push((k.trim().to_string(), v.trim().to_string()));
                    } else if !pair.trim().is_empty() {
                        self.env.push((pair.trim().to_string(), String::new()));
                    }
                }
            }
            // Accepted-and-ignored directives (mail, account, join...).
            "-m" | "-M" | "-A" | "-j" | "-S" | "-r" | "-W" => {}
            other => return Err(Error::parse(format!("unknown directive `{other}`"))),
        }
        Ok(())
    }

    /// `-l walltime=...,mem=...` and `-l nodes=N:ppn=P:prop1:prop2`.
    fn apply_resource_list(&mut self, rest: &str) -> Result<()> {
        for item in rest.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            if let Some(spec) = item.strip_prefix("nodes=") {
                let mut parts = spec.split(':');
                let count = parts.next().unwrap_or("1");
                self.nodes = count
                    .parse()
                    .map_err(|_| Error::parse(format!("bad node count `{count}`")))?;
                if self.nodes == 0 {
                    return Err(Error::parse("nodes must be >= 1"));
                }
                for p in parts {
                    if let Some(ppn) = p.strip_prefix("ppn=") {
                        self.ppn = ppn
                            .parse()
                            .map_err(|_| Error::parse(format!("bad ppn `{ppn}`")))?;
                        if self.ppn == 0 {
                            return Err(Error::parse("ppn must be >= 1"));
                        }
                    } else {
                        self.properties.push(p.to_string());
                    }
                }
            } else if let Some((k, v)) = item.split_once('=') {
                match k.trim() {
                    "walltime" => {
                        self.walltime = parse_walltime(v.trim())
                            .ok_or_else(|| Error::parse(format!("bad walltime `{v}`")))?
                    }
                    "mem" | "pmem" => {
                        self.mem = parse_mem(v.trim())
                            .ok_or_else(|| Error::parse(format!("bad mem `{v}`")))?
                    }
                    _ => {} // ncpus, vmem, etc. accepted-and-ignored
                }
            } else {
                return Err(Error::parse(format!("bad resource item `{item}`")));
            }
        }
        Ok(())
    }

    /// Render back to script text (used when the operator forwards the
    /// embedded script over red-box).
    pub fn render(&self) -> String {
        // If the body opens with a shebang, hoist it above the directives
        // (standard script layout); otherwise emit directives + body
        // verbatim so parse(render(s)) == s.
        let mut body = self.body.as_slice();
        let mut out = String::new();
        if body.first().map(|l| l.starts_with("#!")) == Some(true) {
            out.push_str(&body[0]);
            out.push('\n');
            body = &body[1..];
        }
        if let Some(n) = &self.name {
            out.push_str(&format!("#PBS -N {n}\n"));
        }
        if let Some(q) = &self.queue {
            out.push_str(&format!("#PBS -q {q}\n"));
        }
        out.push_str(&format!(
            "#PBS -l walltime={}\n",
            crate::util::fmt_walltime(self.walltime)
        ));
        let mut nodes = format!("#PBS -l nodes={}", self.nodes);
        if self.ppn != 1 {
            nodes.push_str(&format!(":ppn={}", self.ppn));
        }
        for p in &self.properties {
            nodes.push_str(&format!(":{p}"));
        }
        out.push_str(&nodes);
        out.push('\n');
        if self.mem > 0 {
            out.push_str(&format!("#PBS -l mem={}\n", crate::util::fmt_mem(self.mem)));
        }
        if self.priority != 0 {
            out.push_str(&format!("#PBS -p {}\n", self.priority));
        }
        if let Some(p) = &self.stderr_path {
            out.push_str(&format!("#PBS -e {p}\n"));
        }
        if let Some(p) = &self.stdout_path {
            out.push_str(&format!("#PBS -o {p}\n"));
        }
        if !self.env.is_empty() {
            let pairs: Vec<String> =
                self.env.iter().map(|(k, v)| format!("{k}={v}")).collect();
            out.push_str(&format!("#PBS -v {}\n", pairs.join(",")));
        }
        for line in body {
            out.push_str(line);
            out.push('\n');
        }
        out
    }
}

fn nonempty<'a>(s: &'a str, flag: &str) -> Result<&'a str> {
    if s.is_empty() {
        Err(Error::parse(format!("`{flag}` needs an argument")))
    } else {
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Exactly the embedded script of the paper's Fig. 3.
    const FIG3: &str = "#!/bin/sh\n#PBS -l walltime=00:30:00\n#PBS -l nodes=1\n#PBS -e $HOME/low.err\n#PBS -o $HOME/low.out\nexport PATH=$PATH:/usr/local/bin\nsingularity run lolcow_latest.sif\n";

    #[test]
    fn parses_paper_fig3_script() {
        let s = PbsScript::parse(FIG3).unwrap();
        assert_eq!(s.walltime, Duration::from_secs(1800));
        assert_eq!(s.nodes, 1);
        assert_eq!(s.ppn, 1);
        assert_eq!(s.stderr_path.as_deref(), Some("$HOME/low.err"));
        assert_eq!(s.stdout_path.as_deref(), Some("$HOME/low.out"));
        assert_eq!(
            s.body,
            vec![
                "#!/bin/sh",
                "export PATH=$PATH:/usr/local/bin",
                "singularity run lolcow_latest.sif"
            ]
        );
    }

    #[test]
    fn full_directive_set() {
        let text = "#PBS -N myjob\n#PBS -q gpu\n#PBS -l nodes=4:ppn=8:bigmem,walltime=2:00:00,mem=16gb\n#PBS -p 5\n#PBS -v A=1,B=two\necho hi\n";
        let s = PbsScript::parse(text).unwrap();
        assert_eq!(s.name.as_deref(), Some("myjob"));
        assert_eq!(s.queue.as_deref(), Some("gpu"));
        assert_eq!(s.nodes, 4);
        assert_eq!(s.ppn, 8);
        assert_eq!(s.properties, vec!["bigmem"]);
        assert_eq!(s.walltime, Duration::from_secs(7200));
        assert_eq!(s.mem, 16 << 30);
        assert_eq!(s.priority, 5);
        assert_eq!(s.env, vec![("A".into(), "1".into()), ("B".into(), "two".into())]);
        assert_eq!(s.body, vec!["echo hi"]);
    }

    #[test]
    fn defaults() {
        let s = PbsScript::parse("echo hi\n").unwrap();
        assert_eq!(s.nodes, 1);
        assert_eq!(s.ppn, 1);
        assert_eq!(s.walltime, Duration::from_secs(3600));
        assert!(s.queue.is_none());
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = PbsScript::parse("#PBS -l walltime=abc\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
        assert!(PbsScript::parse("#PBS -l nodes=0\n").is_err());
        assert!(PbsScript::parse("#PBS -l nodes=1:ppn=0\n").is_err());
        assert!(PbsScript::parse("#PBS -p high\n").is_err());
        assert!(PbsScript::parse("#PBS -X whatever\n").is_err());
        assert!(PbsScript::parse("#PBS -N\n").is_err());
    }

    #[test]
    fn ignored_directives_accepted() {
        let s = PbsScript::parse("#PBS -m abe\n#PBS -M a@b.c\n#PBS -j oe\necho x\n").unwrap();
        assert_eq!(s.body, vec!["echo x"]);
    }

    #[test]
    fn render_roundtrip() {
        let text = "#PBS -N r\n#PBS -q batch\n#PBS -l nodes=2:ppn=4:gpu\n#PBS -l walltime=00:10:00,mem=2gb\n#PBS -p 3\n#PBS -e /e\n#PBS -o /o\n#PBS -v X=1\necho body\n";
        let s = PbsScript::parse(text).unwrap();
        let s2 = PbsScript::parse(&s.render()).unwrap();
        assert_eq!(s2, s);
    }

    #[test]
    fn indented_directives() {
        let s = PbsScript::parse("  #PBS -N indent\necho x\n").unwrap();
        assert_eq!(s.name.as_deref(), Some("indent"));
    }
}
