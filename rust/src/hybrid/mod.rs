//! Hybrid testbed: the paper's Fig. 1 architecture in one process.
//!
//! An HPC cluster (pbs_server + compute-node moms, queues) and a big-data
//! cluster (API server + scheduler + kubelets + controllers) joined at the
//! **login node**, which "belongs to both Kubernetes and Torque clusters":
//! it hosts the red-box Unix socket, the Torque/Slurm login services, the
//! kube API RPC surface, the virtual nodes, and both operators.

pub mod testbed;

pub use testbed::{Testbed, TestbedConfig};
