//! Seeded fault injectors for the owned boundaries of the testbed.
//!
//! Two wrappers, one schedule engine:
//!
//! - [`FaultyApi`] decorates an [`ApiClient`] (typically a `RemoteApi`
//!   over the red-box socket) and injects connection drops, delays, and
//!   duplicated requests in front of every unary verb — the red-box
//!   transport fault boundary.
//! - [`FaultyWlm`] decorates a [`WlmBridge`] and makes the HPC side slow
//!   and lossy underneath the operator — submits and status polls fail
//!   transiently or stall, the way a loaded login node behaves.
//!
//! Both draw their decisions from a [`FaultPlan`]: a PCG stream seeded
//! from the scenario seed, so the exact sequence of injected faults is a
//! pure function of `(seed, stream)` and a rerun reproduces it verb for
//! verb. Every injected fault is recorded in a shared [`FaultLog`] with
//! the trace id of the span held open around the faulted call — the same
//! id `hpcorc audit` and `kubectl get events` attribute the downstream
//! effects to.

use crate::encoding::Value;
use crate::kube::{
    ApiClient, BatchPatchItem, EvictionMode, KubeObject, ListOptions, ObjectList, WatchEvent,
};
use crate::operator::{WlmBridge, WlmStatus};
use crate::util::{Error, Result, Rng};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One decision from a [`FaultPlan`].
#[derive(Debug, Clone, PartialEq)]
pub enum Fault {
    /// Let the call through untouched.
    Pass,
    /// Fail the call with an injected transport/backend error.
    Drop,
    /// Stall the call for the given duration, then let it through.
    Delay(Duration),
    /// Execute the call twice (a retransmitted request); the first
    /// result is returned, the duplicate's is discarded.
    Duplicate,
}

impl Fault {
    fn label(&self) -> &'static str {
        match self {
            Fault::Pass => "pass",
            Fault::Drop => "drop",
            Fault::Delay(_) => "delay",
            Fault::Duplicate => "duplicate",
        }
    }
}

/// Seeded, thread-safe fault schedule. Probabilities are per call;
/// whatever remains after drop/delay/duplicate is a clean pass.
#[derive(Debug)]
pub struct FaultPlan {
    rng: Mutex<Rng>,
    drop_p: f64,
    delay_p: f64,
    dup_p: f64,
    max_delay: Duration,
}

impl FaultPlan {
    /// Default mix: 15% drops, 20% delays (up to 2ms), 5% duplicates.
    pub fn new(seed: u64, stream: u64) -> FaultPlan {
        FaultPlan {
            rng: Mutex::new(Rng::with_stream(seed, stream)),
            drop_p: 0.15,
            delay_p: 0.20,
            dup_p: 0.05,
            max_delay: Duration::from_millis(2),
        }
    }

    /// Override the fault mix (each in [0, 1], summing to at most 1).
    pub fn with_mix(mut self, drop_p: f64, delay_p: f64, dup_p: f64) -> FaultPlan {
        self.drop_p = drop_p;
        self.delay_p = delay_p;
        self.dup_p = dup_p;
        self
    }

    pub fn with_max_delay(mut self, d: Duration) -> FaultPlan {
        self.max_delay = d;
        self
    }

    /// Draw the next scheduled fault.
    pub fn next(&self) -> Fault {
        let mut rng = self.rng.lock().unwrap();
        let x = rng.f64();
        if x < self.drop_p {
            Fault::Drop
        } else if x < self.drop_p + self.delay_p {
            let max_us = self.max_delay.as_micros().max(1) as u64;
            Fault::Delay(Duration::from_micros(rng.range(1, max_us)))
        } else if x < self.drop_p + self.delay_p + self.dup_p {
            Fault::Duplicate
        } else {
            Fault::Pass
        }
    }
}

/// One injected fault, as reported by `hpcorc chaos`.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    /// Injection order within the scenario (0-based).
    pub seq: usize,
    /// Boundary the fault was injected at (`api` or `wlm`).
    pub boundary: &'static str,
    /// The faulted operation, e.g. `create Pod/p0` or `wlm submit`.
    pub op: String,
    /// `drop` | `delay` | `duplicate`.
    pub fault: String,
    /// Wire rendering of the chaos span held around the faulted call —
    /// the id `hpcorc audit` / `hpcorc trace` attribute effects to.
    pub trace: String,
}

/// Shared sink for injected-fault records (cheap to clone).
#[derive(Debug, Clone, Default)]
pub struct FaultLog {
    records: Arc<Mutex<Vec<FaultRecord>>>,
}

impl FaultLog {
    pub fn new() -> FaultLog {
        FaultLog::default()
    }

    fn record(&self, boundary: &'static str, op: &str, fault: &Fault, trace: String) {
        let mut rs = self.records.lock().unwrap();
        let seq = rs.len();
        rs.push(FaultRecord {
            seq,
            boundary,
            op: op.to_string(),
            fault: fault.label().to_string(),
            trace,
        });
    }

    pub fn take(&self) -> Vec<FaultRecord> {
        std::mem::take(&mut *self.records.lock().unwrap())
    }

    pub fn len(&self) -> usize {
        self.records.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Run `f` under one scheduled fault decision, recording any injection
/// into `log` with the trace id of a span held open across the call —
/// so the server-side audit record / object annotations of a delayed or
/// duplicated call parent on the chaos trace.
fn inject<T>(
    plan: &FaultPlan,
    log: &FaultLog,
    boundary: &'static str,
    op: &str,
    err: impl FnOnce(String) -> Error,
    f: impl Fn() -> Result<T>,
) -> Result<T> {
    let fault = plan.next();
    if fault == Fault::Pass {
        return f();
    }
    let _actor = crate::obs::push_actor("chaos");
    let span = crate::obs::span("chaos", &format!("fault {} {op}", fault.label()));
    let trace = span.context().map(|c| c.to_wire()).unwrap_or_default();
    log.record(boundary, op, &fault, trace);
    match fault {
        Fault::Pass => unreachable!(),
        Fault::Drop => Err(err(format!("chaos: injected {boundary} drop on {op}"))),
        Fault::Delay(d) => {
            std::thread::sleep(d);
            f()
        }
        Fault::Duplicate => {
            let first = f();
            let _ = f(); // the retransmission; result discarded
            first
        }
    }
}

// ------------------------------------------------------------ red-box side

/// [`ApiClient`] decorator injecting seeded transport faults in front of
/// every unary verb. Watches pass through untouched (stream loss has its
/// own scenario — the history-overflow one). Wrap a `RemoteApi` to model
/// red-box connection trouble; the consumer must survive on retries.
pub struct FaultyApi {
    inner: Arc<dyn ApiClient>,
    plan: FaultPlan,
    log: FaultLog,
}

impl FaultyApi {
    pub fn new(inner: Arc<dyn ApiClient>, plan: FaultPlan, log: FaultLog) -> FaultyApi {
        FaultyApi { inner, plan, log }
    }

    fn gate<T>(&self, op: String, f: impl Fn() -> Result<T>) -> Result<T> {
        inject(&self.plan, &self.log, "api", &op, Error::rpc, f)
    }
}

impl ApiClient for FaultyApi {
    fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        let op = format!("create {}/{}", obj.kind, obj.meta.name);
        self.gate(op, || self.inner.create(obj.clone()))
    }
    fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.gate(format!("get {kind}/{name}"), || self.inner.get(kind, name))
    }
    fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        let op = format!("update {}/{}", obj.kind, obj.meta.name);
        self.gate(op, || self.inner.update(obj.clone()))
    }
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        self.gate(format!("update_status {kind}/{name}"), || {
            self.inner.update_status(kind, name, f)
        })
    }
    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        self.gate(format!("patch {kind}/{name}"), || {
            self.inner.patch_merge(kind, name, patch)
        })
    }
    fn update_status_batch(
        &self,
        items: &[BatchPatchItem],
    ) -> Result<Vec<Result<KubeObject>>> {
        self.gate(format!("update_status_batch x{}", items.len()), || {
            self.inner.update_status_batch(items)
        })
    }
    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.gate(format!("delete {kind}/{name}"), || self.inner.delete(kind, name))
    }
    fn evict(&self, name: &str, mode: &EvictionMode) -> Result<KubeObject> {
        self.gate(format!("evict Pod/{name}"), || self.inner.evict(name, mode))
    }
    fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        let op = format!("apply {}/{}", obj.kind, obj.meta.name);
        self.gate(op, || self.inner.apply(obj.clone()))
    }
    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        self.gate(format!("list {kind}"), || self.inner.list(kind, opts))
    }
    fn watch(&self, kind: Option<&str>, from_version: u64) -> Result<Receiver<WatchEvent>> {
        self.inner.watch(kind, from_version)
    }
    fn server_time_s(&self) -> Result<f64> {
        self.inner.server_time_s()
    }
}

// --------------------------------------------------------------- WLM side

/// [`WlmBridge`] decorator making the HPC backend slow and lossy: submit
/// and status calls transiently fail or stall per the plan. Plugs into
/// [`crate::hybrid::TestbedConfig::wlm_shim`]; the operator's
/// backoff-and-retry reconcile loop must absorb every injected failure.
pub struct FaultyWlm {
    inner: Arc<dyn WlmBridge>,
    plan: FaultPlan,
    log: FaultLog,
}

impl FaultyWlm {
    pub fn new(inner: Arc<dyn WlmBridge>, plan: FaultPlan, log: FaultLog) -> FaultyWlm {
        FaultyWlm { inner, plan, log }
    }

    fn gate<T>(&self, op: &str, f: impl Fn() -> Result<T>) -> Result<T> {
        inject(&self.plan, &self.log, "wlm", op, Error::wlm, f)
    }
}

impl WlmBridge for FaultyWlm {
    fn submit(&self, script: &str, user: &str) -> Result<String> {
        self.gate("wlm submit", || self.inner.submit(script, user))
    }
    fn status(&self, job_id: &str) -> Result<WlmStatus> {
        self.gate(&format!("wlm status {job_id}"), || self.inner.status(job_id))
    }
    fn cancel(&self, job_id: &str) -> Result<()> {
        self.gate(&format!("wlm cancel {job_id}"), || self.inner.cancel(job_id))
    }
    fn read_file(&self, path: &str) -> Result<String> {
        self.gate(&format!("wlm read {path}"), || self.inner.read_file(path))
    }
    fn write_file(&self, path: &str, content: &str) -> Result<()> {
        self.gate(&format!("wlm write {path}"), || self.inner.write_file(path, content))
    }
    fn queues(&self) -> Result<Vec<String>> {
        self.gate("wlm queues", || self.inner.queues())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_seed_deterministic() {
        let a = FaultPlan::new(42, 1);
        let b = FaultPlan::new(42, 1);
        let seq_a: Vec<Fault> = (0..200).map(|_| a.next()).collect();
        let seq_b: Vec<Fault> = (0..200).map(|_| b.next()).collect();
        assert_eq!(seq_a, seq_b);
        // A different stream diverges.
        let c = FaultPlan::new(42, 2);
        let seq_c: Vec<Fault> = (0..200).map(|_| c.next()).collect();
        assert_ne!(seq_a, seq_c);
        // The mix actually injects something.
        assert!(seq_a.iter().any(|f| *f != Fault::Pass));
        assert!(seq_a.iter().any(|f| *f == Fault::Pass));
    }

    #[test]
    fn faulty_api_drops_and_recovers() {
        use crate::kube::{ApiServer, PodView};
        use crate::cluster::{Metrics, Resources};
        let server = ApiServer::new(Metrics::new());
        let log = FaultLog::new();
        // Drop everything: every call must fail with an injected error.
        let all_drops = FaultPlan::new(7, 0).with_mix(1.0, 0.0, 0.0);
        let api = FaultyApi::new(server.client(), all_drops, log.clone());
        let pod = PodView::build("p0", "x.sif", Resources::new(100, 0, 0), &[]);
        let err = api.create(pod.clone()).unwrap_err();
        assert!(err.to_string().contains("chaos: injected api drop"));
        assert_eq!(log.len(), 1);
        // Pass-through plan: the same call lands.
        let clean = FaultPlan::new(7, 1).with_mix(0.0, 0.0, 0.0);
        let api = FaultyApi::new(server.client(), clean, log.clone());
        api.create(pod).unwrap();
        assert!(server.get("Pod", "p0").is_ok());
        assert_eq!(log.len(), 1, "clean passes are not recorded");
        // Fault records carry a trace id for audit attribution.
        assert!(!log.take()[0].trace.is_empty());
    }

    #[test]
    fn duplicate_returns_first_result() {
        use crate::kube::{ApiServer, PodView};
        use crate::cluster::{Metrics, Resources};
        let server = ApiServer::new(Metrics::new());
        let log = FaultLog::new();
        let dups = FaultPlan::new(3, 0).with_mix(0.0, 0.0, 1.0);
        let api = FaultyApi::new(server.client(), dups, log.clone());
        let pod = PodView::build("dup", "x.sif", Resources::new(100, 0, 0), &[]);
        // First create succeeds; the duplicate's AlreadyExists is swallowed.
        api.create(pod).unwrap();
        assert_eq!(log.len(), 1);
        assert_eq!(log.take()[0].fault, "duplicate");
    }
}
