"""L2 correctness: the crop-yield transformer.

- Pallas-forward network matches the pure-jnp reference network.
- Shapes are right across configs.
- The exported train step actually learns (loss decreases on the synthetic
  teacher task) — the property the e2e example then demonstrates from Rust.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


CFG = model.CONFIGS["tiny"]


def test_param_shapes_and_count():
    params = model.init_params(jax.random.PRNGKey(0), CFG)
    specs = model.param_specs(CFG)
    assert len(params) == len(specs) == 2 + CFG["n_layers"] * 8 + 2
    for p, s in zip(params, specs):
        assert p.shape == s.shape
        assert p.dtype == jnp.float32


def test_forward_matches_ref_network():
    params = model.init_params(jax.random.PRNGKey(1), CFG)
    x, _ = model.synth_batch(0, CFG)
    out = model.forward(params, x, CFG)
    expect = model.forward_ref(params, x, CFG)
    assert out.shape == (CFG["batch"],)
    np.testing.assert_allclose(out, expect, rtol=5e-4, atol=5e-4)


def test_grads_match_ref_network():
    params = model.init_params(jax.random.PRNGKey(2), CFG)
    x, y = model.synth_batch(3, CFG)

    def loss_kernel(params):
        return jnp.mean((model.forward(params, x, CFG) - y) ** 2)

    def loss_ref(params):
        return jnp.mean((model.forward_ref(params, x, CFG) - y) ** 2)

    gk = jax.grad(loss_kernel)(params)
    gr = jax.grad(loss_ref)(params)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=3e-3, atol=3e-3)


def test_synth_batch_deterministic_and_learnable_signal():
    x1, y1 = model.synth_batch(5, CFG)
    x2, y2 = model.synth_batch(5, CFG)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = model.synth_batch(6, CFG)
    assert not np.array_equal(np.asarray(x1), np.asarray(x3))
    # Teacher outputs have real variance (not a degenerate target).
    assert float(jnp.std(y1)) > 0.01


def test_train_step_reduces_loss():
    init_fn = model.make_init_fn(CFG)
    step_fn = jax.jit(model.make_train_step_fn(CFG))
    params = list(init_fn(0))
    losses = []
    for step in range(30):
        out = step_fn(jnp.int32(step), *params)
        params = list(out[:-1])
        losses.append(float(out[-1]))
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first * 0.8, f"loss did not decrease: {first:.4f} -> {last:.4f}"


def test_infer_fn_shapes():
    cfg = CFG
    init_fn = model.make_init_fn(cfg)
    infer_fn = jax.jit(model.make_infer_fn(cfg))
    params = list(init_fn(0))
    yhat, mse = infer_fn(jnp.int32(0), *params)
    assert yhat.shape == (cfg["batch"],)
    assert mse.shape == ()
    assert float(mse) >= 0.0


def test_flops_estimate_positive_and_monotone():
    tiny = model.flops_per_step(model.CONFIGS["tiny"])
    small = model.flops_per_step(model.CONFIGS["small"])
    assert 0 < tiny < small
