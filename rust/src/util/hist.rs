//! Log-bucketed latency histogram (HdrHistogram-lite).
//!
//! Used by the metrics registry and the bench harness. Buckets are
//! exponential with 32 sub-buckets per octave, giving ~2-3% relative error
//! on quantiles over a microsecond..hours range — plenty for scheduling
//! latencies.

/// A histogram of non-negative u64 samples (typically nanoseconds).
#[derive(Debug, Clone)]
pub struct Hist {
    /// buckets[o][s]: octave o (value ~ 2^o), sub-bucket s of 32.
    buckets: Vec<[u64; 32]>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    pub fn new() -> Self {
        Hist { buckets: vec![[0; 32]; 64], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    fn index(v: u64) -> (usize, usize) {
        if v < 32 {
            return (0, v as usize);
        }
        let octave = 63 - v.leading_zeros() as usize; // floor(log2 v), >= 5
        let sub = ((v >> (octave - 5)) & 31) as usize;
        (octave - 4, sub)
    }

    /// Representative (upper-edge) value for a bucket.
    fn value(oct: usize, sub: usize) -> u64 {
        if oct == 0 {
            return sub as u64;
        }
        let octave = oct + 4;
        (1u64 << octave) + ((sub as u64) << (octave - 5))
    }

    pub fn record(&mut self, v: u64) {
        let (o, s) = Self::index(v);
        self.buckets[o][s] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn record_n(&mut self, v: u64, n: u64) {
        let (o, s) = Self::index(v);
        self.buckets[o][s] += n;
        self.count += n;
        self.sum += v as u128 * n as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile in [0,1]; returns the bucket's representative value.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.count as f64).ceil() as u64;
        let target = target.max(1);
        let mut seen = 0u64;
        for (o, sub) in self.buckets.iter().enumerate() {
            for (s, c) in sub.iter().enumerate() {
                seen += c;
                if seen >= target {
                    return Self::value(o, s).min(self.max).max(self.min);
                }
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Exact sum of all recorded samples.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Non-empty buckets as `(upper_bound, count)` in ascending bound
    /// order — what the Prometheus exposition renders as cumulative
    /// `le` buckets without walking 2048 empty slots. The bound is the
    /// bucket's upper edge (the next bucket's lower edge), so every
    /// sample in the bucket satisfies `v <= bound`.
    pub fn buckets_nonzero(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for (o, sub) in self.buckets.iter().enumerate() {
            for (s, c) in sub.iter().enumerate() {
                if *c == 0 {
                    continue;
                }
                let bound = match (s, o + 1 < self.buckets.len()) {
                    (31, true) => Self::value(o + 1, 0),
                    (31, false) => u64::MAX,
                    _ => Self::value(o, s + 1),
                };
                out.push((bound, *c));
            }
        }
        out
    }

    /// Merge another histogram into this one (for per-thread aggregation).
    pub fn merge(&mut self, other: &Hist) {
        for (o, sub) in other.buckets.iter().enumerate() {
            for (s, c) in sub.iter().enumerate() {
                self.buckets[o][s] += c;
            }
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    /// Human summary with a nanosecond→unit scale, e.g. `summary(1e6, "ms")`.
    pub fn summary(&self, scale: f64, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p95={:.3}{u} p99={:.3}{u} max={:.3}{u}",
            self.count,
            self.mean() / scale,
            self.p50() as f64 / scale,
            self.p95() as f64 / scale,
            self.p99() as f64 / scale,
            self.max as f64 / scale,
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_hist() {
        let h = Hist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn exact_small_values() {
        let mut h = Hist::new();
        for v in 0..32 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 31);
        assert_eq!(h.count(), 32);
    }

    #[test]
    fn quantiles_within_relative_error() {
        let mut h = Hist::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for (q, expect) in [(0.5, 50_000.0), (0.95, 95_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            assert!(
                (got - expect).abs() / expect < 0.05,
                "q={q} got={got} expect={expect}"
            );
        }
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Hist::new();
        h.record(10);
        h.record(20);
        h.record(30);
        assert_eq!(h.mean(), 20.0);
    }

    #[test]
    fn merge_combines() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        a.record(100);
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 100);
        assert_eq!(a.max(), 1_000_000);
    }

    #[test]
    fn record_n_counts() {
        let mut h = Hist::new();
        h.record_n(500, 10);
        assert_eq!(h.count(), 10);
        assert_eq!(h.mean(), 500.0);
    }

    #[test]
    fn nonzero_buckets_cover_all_samples() {
        let mut h = Hist::new();
        for v in [0u64, 5, 100, 3000, 1_000_000] {
            h.record(v);
        }
        let buckets = h.buckets_nonzero();
        assert_eq!(buckets.iter().map(|(_, c)| c).sum::<u64>(), h.count());
        // Ascending bounds, and every sample fits under the last bound.
        assert!(buckets.windows(2).all(|w| w[0].0 < w[1].0));
        assert!(buckets.last().unwrap().0 >= 1_000_000);
        assert_eq!(h.sum(), 1_003_105);
    }

    #[test]
    fn index_roundtrip_monotone() {
        // value(index(v)) stays within one sub-bucket width of v.
        for v in [0u64, 1, 31, 32, 33, 100, 1023, 1024, 123_456_789, u32::MAX as u64] {
            let (o, s) = Hist::index(v);
            let rep = Hist::value(o, s);
            assert!(rep <= v.max(1) * 2, "v={v} rep={rep}");
            assert!(rep as f64 >= v as f64 * 0.95 || v < 64, "v={v} rep={rep}");
        }
    }
}
