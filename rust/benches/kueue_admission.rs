//! Queue-layer cost: what does one admission cycle cost as the pending
//! backlog grows? Three shapes per backlog size (1k / 10k queued
//! workloads):
//!
//! - **first cycle** — the admission burst: quota-limited admissions plus
//!   their status writes;
//! - **steady cycle** — everything admitted/blocked already: pure
//!   list + gang-build + ledger arithmetic, the recurring price every
//!   queue/workload event pays;
//! - **ledger fit** — the pure quota check, the per-gang floor.

use hpcorc::bench::{header, Bench};
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::kube::{ApiServer, PodView};
use hpcorc::kueue::{
    AdmissionCore, ClusterQueueView, Ledger, LocalQueueView, QueueResources, QUEUE_NAME_LABEL,
};

const QUOTA_NODES: u32 = 64;
const TENANTS: usize = 4;

fn setup(n_workloads: usize) -> ApiServer {
    let api = ApiServer::new(Metrics::new());
    for t in 0..TENANTS {
        api.create(ClusterQueueView::build(
            &format!("cq-{t}"),
            QueueResources::nodes(QUOTA_NODES),
        ))
        .unwrap();
        api.create(LocalQueueView::build(&format!("team-{t}"), &format!("cq-{t}"))).unwrap();
    }
    for i in 0..n_workloads {
        let mut pod = PodView::build(
            &format!("pod-{i:06}"),
            "lolcow_latest.sif",
            Resources::new(100, 1 << 20, 0),
            &[],
        );
        pod.meta.set_label(QUEUE_NAME_LABEL, &format!("team-{}", i % TENANTS));
        api.create(pod).unwrap();
    }
    api
}

fn main() {
    println!(
        "=== kueue admission cycle: {TENANTS} tenants x {QUOTA_NODES}-node quotas ==="
    );
    println!("{}", header());

    for n in [1_000usize, 10_000] {
        let api = setup(n);
        let informers =
            hpcorc::kube::SharedInformerFactory::new(api.client(), Metrics::new());
        let core = AdmissionCore::new(&informers, Metrics::new());
        // The admission burst (one-shot: every admitted pod is written).
        Bench::new(format!("first cycle ({n} queued)")).warmup(0).iters(1).run(|| {
            let r = core.cycle(&api).unwrap();
            // Idempotent across the (single) iteration by construction:
            // only the first cycle admits, so assert on ">= 0" shape via
            // pending instead of admitted.
            assert!(r.admitted + r.pending > 0);
        });
        // Steady state: nothing changes, no writes — the recurring cost.
        Bench::new(format!("steady cycle ({n} queued)")).warmup(2).iters(15).run(|| {
            let r = core.cycle(&api).unwrap();
            assert_eq!(r.admitted, 0);
        });
    }

    // The pure ledger floor: fit+charge for one gang among 64 queues.
    let views: Vec<ClusterQueueView> = (0..64)
        .map(|i| {
            ClusterQueueView::from_object(&ClusterQueueView::build(
                &format!("cq-{i}"),
                QueueResources::nodes(QUOTA_NODES),
            ))
            .unwrap()
        })
        .collect();
    let ledger = Ledger::new(views);
    let demand = QueueResources { nodes: 4, cpu_milli: 4000, mem_bytes: 4 << 30 };
    Bench::new("ledger fit (64 queues)").warmup(100).iters(5000).run(|| {
        assert!(ledger.fit("cq-32", &demand).admissible());
    });
}
