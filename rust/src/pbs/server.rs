//! pbs_server: job registry, state machine, queue admission, and the
//! scheduling loop that dispatches to pbs_moms.
//!
//! Job states follow Torque: `Q` (queued) → `R` (running) → `C` (completed),
//! with `H` (held) and deletion (qdel) side paths. Time inside the server
//! is *nominal* seconds (`now_s = elapsed_real / time_scale`), so walltimes
//! and backfill reservations behave identically whether the testbed runs
//! in real time or 1000× compressed.

use super::mom::{JobDone, LaunchSpec, Mom};
use super::queue::{QueueConfig, QueueSet};
use super::script::PbsScript;
use crate::cluster::{Metrics, NodeSpec, SharedFs};
use crate::rt::{self, Shutdown, Timers};
use crate::sched::{NodeState, PendingJob, RunningJob, SchedPolicy};
use crate::singularity::Runtime;
use crate::util::{Error, JobId, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Torque job states (qstat letters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Held,
    Running,
    Completed,
}

impl JobState {
    pub fn letter(&self) -> char {
        match self {
            JobState::Queued => 'Q',
            JobState::Held => 'H',
            JobState::Running => 'R',
            JobState::Completed => 'C',
        }
    }
}

/// One job's record in the server.
#[derive(Debug, Clone)]
pub struct Job {
    pub seq: u64,
    pub id: JobId,
    pub script: PbsScript,
    pub queue: String,
    pub user: String,
    pub state: JobState,
    pub submit_s: f64,
    pub start_s: Option<f64>,
    pub end_s: Option<f64>,
    pub placement: Vec<String>,
    pub exit_code: Option<i32>,
    pub cancelled: bool,
    pub walltime_exceeded: bool,
}

impl Job {
    pub fn name(&self) -> &str {
        self.script.name.as_deref().unwrap_or("STDIN")
    }
}

/// Accounting log record (Torque's accounting `E` record, distilled).
#[derive(Debug, Clone)]
pub struct AcctRecord {
    pub seq: u64,
    pub user: String,
    pub queue: String,
    pub submit_s: f64,
    pub start_s: f64,
    pub end_s: f64,
    pub nodes: u32,
    pub ppn: u32,
    pub exit_code: i32,
}

struct NodeAlloc {
    spec: NodeSpec,
    used_cores: u32,
    used_mem: u64,
}

struct SrvState {
    jobs: BTreeMap<u64, Job>,
    nodes: Vec<NodeAlloc>,
    accounting: Vec<AcctRecord>,
}

pub struct PbsConfig {
    pub server_name: String,
    pub queues: Vec<QueueConfig>,
    /// Real-time period between scheduling cycles.
    pub sched_period: Duration,
    /// Nominal→real compression (0.001 = "30 minutes" runs in 1.8 s).
    pub time_scale: f64,
}

impl Default for PbsConfig {
    fn default() -> Self {
        PbsConfig {
            server_name: "torque-head".into(),
            queues: vec![QueueConfig::batch(&[])],
            sched_period: Duration::from_millis(5),
            time_scale: 1.0,
        }
    }
}

/// The pbs_server handle (cheap clone).
#[derive(Clone)]
pub struct PbsServer {
    inner: Arc<Inner>,
}

struct Inner {
    name: String,
    queues: QueueSet,
    policy: Box<dyn SchedPolicy>,
    state: Mutex<SrvState>,
    moms: Mutex<HashMap<String, Mom>>,
    metrics: Metrics,
    time_scale: f64,
    epoch: Instant,
    seq: AtomicU64,
    fs: SharedFs,
}

impl PbsServer {
    /// Boot the server: registers a mom per compute node, starts the event
    /// loop and the scheduler ticker.
    pub fn start(
        config: PbsConfig,
        compute_nodes: Vec<NodeSpec>,
        runtime: Runtime,
        fs: SharedFs,
        policy: Box<dyn SchedPolicy>,
        timers: Timers,
        metrics: Metrics,
        shutdown: Shutdown,
    ) -> Result<PbsServer> {
        let queues = QueueSet::new(config.queues)?;
        let (done_tx, done_rx) = channel::<JobDone>();
        let mut moms = HashMap::new();
        for spec in &compute_nodes {
            let mom = Mom::new(
                spec.clone(),
                fs.clone(),
                runtime.clone(),
                timers.clone(),
                config.time_scale,
                done_tx.clone(),
                metrics.clone(),
                shutdown.clone(),
            );
            moms.insert(spec.name.clone(), mom);
        }
        let inner = Arc::new(Inner {
            name: config.server_name,
            queues,
            policy,
            state: Mutex::new(SrvState {
                jobs: BTreeMap::new(),
                nodes: compute_nodes
                    .into_iter()
                    .map(|spec| NodeAlloc { spec, used_cores: 0, used_mem: 0 })
                    .collect(),
                accounting: Vec::new(),
            }),
            moms: Mutex::new(moms),
            metrics,
            time_scale: config.time_scale.max(1e-9),
            epoch: Instant::now(),
            seq: AtomicU64::new(1),
            fs,
        });
        let server = PbsServer { inner };

        // Completion event loop.
        let srv2 = server.clone();
        let sd2 = shutdown.clone();
        rt::spawn_named("pbs-events", move || loop {
            match done_rx.recv_timeout(Duration::from_millis(20)) {
                Ok(done) => srv2.on_job_done(done),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if sd2.is_triggered() {
                        return;
                    }
                }
                Err(_) => return,
            }
        });

        // Scheduler ticker.
        let srv3 = server.clone();
        rt::pool::spawn_ticker("pbs-sched", config.sched_period, shutdown, move || {
            srv3.run_sched_cycle();
        });
        Ok(server)
    }

    pub fn server_name(&self) -> &str {
        &self.inner.name
    }

    pub fn fs(&self) -> &SharedFs {
        &self.inner.fs
    }

    pub fn queues(&self) -> &QueueSet {
        &self.inner.queues
    }

    pub fn time_scale(&self) -> f64 {
        self.inner.time_scale
    }

    /// Nominal seconds since server boot.
    pub fn now_s(&self) -> f64 {
        self.inner.epoch.elapsed().as_secs_f64() / self.inner.time_scale
    }

    // ------------------------------------------------------------- commands

    /// `qsub`: submit a PBS script. Returns the job id (`<seq>.<server>`).
    pub fn qsub(&self, script_text: &str, user: &str) -> Result<JobId> {
        let script = PbsScript::parse(script_text)?;
        self.qsub_parsed(script, user)
    }

    pub fn qsub_parsed(&self, script: PbsScript, user: &str) -> Result<JobId> {
        let queue = self.inner.queues.resolve(script.queue.as_deref())?.clone();
        {
            let state = self.inner.state.lock().unwrap();
            let depth = state
                .jobs
                .values()
                .filter(|j| j.queue == queue.name && j.state != JobState::Completed)
                .count();
            queue.admit(&script, user, depth)?;
            // Reject jobs that can never run (no node is big enough).
            let feasible = state.nodes.iter().filter(|n| node_matches(n, &script)).count()
                >= script.nodes as usize;
            if !feasible {
                return Err(Error::wlm(format!(
                    "job requests {} node(s) with ppn={} — queue `{}` cannot ever satisfy it",
                    script.nodes, script.ppn, queue.name
                )));
            }
        }
        let seq = self.inner.seq.fetch_add(1, Ordering::Relaxed);
        let id = JobId::new(seq, &self.inner.name);
        let job = Job {
            seq,
            id: id.clone(),
            script,
            queue: queue.name.clone(),
            user: user.to_string(),
            state: JobState::Queued,
            submit_s: self.now_s(),
            start_s: None,
            end_s: None,
            placement: Vec::new(),
            exit_code: None,
            cancelled: false,
            walltime_exceeded: false,
        };
        self.inner.state.lock().unwrap().jobs.insert(seq, job);
        self.inner.metrics.inc("pbs.jobs_submitted");
        Ok(id)
    }

    /// `qstat`: all jobs (completed included, like `qstat -x`).
    pub fn qstat(&self) -> Vec<Job> {
        self.inner.state.lock().unwrap().jobs.values().cloned().collect()
    }

    pub fn qstat_job(&self, seq: u64) -> Result<Job> {
        self.inner
            .state
            .lock()
            .unwrap()
            .jobs
            .get(&seq)
            .cloned()
            .ok_or_else(|| Error::wlm(format!("qstat: unknown job {seq}")))
    }

    /// `qdel`: cancel a job.
    pub fn qdel(&self, seq: u64) -> Result<()> {
        let mom_to_cancel = {
            let mut state = self.inner.state.lock().unwrap();
            let now = self.now_s();
            let job = state
                .jobs
                .get_mut(&seq)
                .ok_or_else(|| Error::wlm(format!("qdel: unknown job {seq}")))?;
            match job.state {
                JobState::Queued | JobState::Held => {
                    job.state = JobState::Completed;
                    job.cancelled = true;
                    job.end_s = Some(now);
                    job.exit_code = Some(271); // Torque's qdel exit status
                    None
                }
                JobState::Running => {
                    job.cancelled = true;
                    job.placement.first().cloned()
                }
                JobState::Completed => None,
            }
        };
        if let Some(node) = mom_to_cancel {
            if let Some(mom) = self.inner.moms.lock().unwrap().get(&node) {
                mom.cancel(seq);
            }
        }
        self.inner.metrics.inc("pbs.jobs_deleted");
        Ok(())
    }

    /// `qhold` / `qrls`.
    pub fn qhold(&self, seq: u64) -> Result<()> {
        self.transition(seq, JobState::Queued, JobState::Held, "qhold")
    }

    pub fn qrls(&self, seq: u64) -> Result<()> {
        self.transition(seq, JobState::Held, JobState::Queued, "qrls")
    }

    fn transition(&self, seq: u64, from: JobState, to: JobState, verb: &str) -> Result<()> {
        let mut state = self.inner.state.lock().unwrap();
        let job = state
            .jobs
            .get_mut(&seq)
            .ok_or_else(|| Error::wlm(format!("{verb}: unknown job {seq}")))?;
        if job.state != from {
            return Err(Error::wlm(format!(
                "{verb}: job {seq} is {:?}, expected {:?}",
                job.state, from
            )));
        }
        job.state = to;
        Ok(())
    }

    /// `qalter`: modify a queued job's priority and/or walltime.
    pub fn qalter(
        &self,
        seq: u64,
        priority: Option<i64>,
        walltime: Option<Duration>,
    ) -> Result<()> {
        let mut state = self.inner.state.lock().unwrap();
        let job = state
            .jobs
            .get_mut(&seq)
            .ok_or_else(|| Error::wlm(format!("qalter: unknown job {seq}")))?;
        if !matches!(job.state, JobState::Queued | JobState::Held) {
            return Err(Error::wlm(format!("qalter: job {seq} already started")));
        }
        if let Some(p) = priority {
            job.script.priority = p;
        }
        if let Some(w) = walltime {
            job.script.walltime = w;
        }
        Ok(())
    }

    /// `pbsnodes`: per-node allocation view `(name, used_cores, total_cores)`.
    pub fn pbsnodes(&self) -> Vec<(String, u32, u32)> {
        self.inner
            .state
            .lock()
            .unwrap()
            .nodes
            .iter()
            .map(|n| {
                (n.spec.name.clone(), n.used_cores, (n.spec.capacity.cpu_milli / 1000) as u32)
            })
            .collect()
    }

    pub fn accounting(&self) -> Vec<AcctRecord> {
        self.inner.state.lock().unwrap().accounting.clone()
    }

    /// Block until a job completes (tests, the operator's status loop uses
    /// polling instead).
    pub fn wait_for(&self, seq: u64, timeout: Duration) -> Result<Job> {
        let deadline = Instant::now() + timeout;
        loop {
            let job = self.qstat_job(seq)?;
            if job.state == JobState::Completed {
                return Ok(job);
            }
            if Instant::now() >= deadline {
                return Err(Error::wlm(format!("timeout waiting for job {seq}")));
            }
            std::thread::sleep(Duration::from_micros(500));
        }
    }

    // ------------------------------------------------------------ scheduling

    /// One scheduling cycle. Public so tests/benches can step deterministically.
    pub fn run_sched_cycle(&self) {
        let now = self.now_s();
        let t0 = Instant::now();
        let launches = {
            let mut state = self.inner.state.lock().unwrap();
            let mut launches: Vec<(String, LaunchSpec)> = Vec::new();
            // Queues in priority order, highest first.
            let mut queue_order: Vec<&QueueConfig> = self.inner.queues.iter().collect();
            queue_order.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.name.cmp(&b.name)));
            for queue in queue_order {
                // Group pending by property-set so feature-constrained jobs
                // only see matching nodes (simplification documented in
                // DESIGN.md: property groups are scheduled sequentially).
                let mut prop_groups: Vec<Vec<String>> = Vec::new();
                for j in state.jobs.values() {
                    if j.state == JobState::Queued && j.queue == queue.name {
                        let props = j.script.properties.clone();
                        if !prop_groups.contains(&props) {
                            prop_groups.push(props);
                        }
                    }
                }
                for props in prop_groups {
                    let pending: Vec<PendingJob> = state
                        .jobs
                        .values()
                        .filter(|j| {
                            j.state == JobState::Queued
                                && j.queue == queue.name
                                && j.script.properties == props
                        })
                        .map(|j| PendingJob {
                            id: j.seq,
                            nodes: j.script.nodes,
                            ppn: j.script.ppn,
                            mem: j.script.mem,
                            walltime: j.script.walltime,
                            priority: j.script.priority + queue.priority,
                            submit_s: j.submit_s,
                            queue: Some(j.queue.clone()),
                        })
                        .collect();
                    if pending.is_empty() {
                        continue;
                    }
                    let (node_states, index_to_name) =
                        snapshot_nodes(&state, queue, &props);
                    if node_states.is_empty() {
                        continue;
                    }
                    let running = snapshot_running(&state, &index_to_name);
                    let assignments =
                        self.inner.policy.schedule(now, &pending, &node_states, &running);
                    for a in assignments {
                        let names: Vec<String> =
                            a.placement.iter().map(|p| index_to_name[p.node].clone()).collect();
                        let job = state.jobs.get_mut(&a.job).expect("assigned job exists");
                        job.state = JobState::Running;
                        job.start_s = Some(now);
                        job.placement = names.clone();
                        let spec = LaunchSpec {
                            job_seq: job.seq,
                            job_name: job.name().to_string(),
                            body: job.script.body.clone(),
                            env: job.script.env.clone(),
                            stdout_path: job.script.stdout_path.clone(),
                            stderr_path: job.script.stderr_path.clone(),
                            walltime: job.script.walltime,
                            seed: job.seq,
                        };
                        let ppn = job.script.ppn;
                        let mem = job.script.mem;
                        for name in &names {
                            let alloc = state
                                .nodes
                                .iter_mut()
                                .find(|n| &n.spec.name == name)
                                .expect("placement node exists");
                            alloc.used_cores += ppn;
                            alloc.used_mem += mem;
                        }
                        // wait time in nominal seconds → histogram in µs units
                        let wait = now - state.jobs[&a.job].submit_s;
                        self.inner
                            .metrics
                            .observe("pbs.wait_nominal_us", (wait * 1e6).max(0.0) as u64);
                        launches.push((names[0].clone(), spec));
                    }
                }
            }
            launches
        };
        for (node, spec) in launches {
            if let Some(mom) = self.inner.moms.lock().unwrap().get(&node) {
                self.inner.metrics.inc("pbs.jobs_started");
                mom.launch(spec);
            }
        }
        self.inner.metrics.inc("pbs.sched_cycles");
        self.inner.metrics.observe("pbs.sched_cycle_ns", t0.elapsed().as_nanos() as u64);
    }

    fn on_job_done(&self, done: JobDone) {
        let mut state = self.inner.state.lock().unwrap();
        let now = self.now_s();
        let Some(job) = state.jobs.get_mut(&done.job_seq) else { return };
        if job.state != JobState::Running {
            return; // duplicate/stale report
        }
        job.state = JobState::Completed;
        job.end_s = Some(now);
        job.exit_code = Some(done.exit_code);
        job.walltime_exceeded = done.walltime_exceeded;
        job.cancelled = job.cancelled || done.cancelled;
        let record = AcctRecord {
            seq: job.seq,
            user: job.user.clone(),
            queue: job.queue.clone(),
            submit_s: job.submit_s,
            start_s: job.start_s.unwrap_or(now),
            end_s: now,
            nodes: job.script.nodes,
            ppn: job.script.ppn,
            exit_code: done.exit_code,
        };
        let ppn = job.script.ppn;
        let mem = job.script.mem;
        let placement = job.placement.clone();
        for name in &placement {
            if let Some(alloc) = state.nodes.iter_mut().find(|n| &n.spec.name == name) {
                alloc.used_cores = alloc.used_cores.saturating_sub(ppn);
                alloc.used_mem = alloc.used_mem.saturating_sub(mem);
            }
        }
        state.accounting.push(record);
        self.inner.metrics.inc("pbs.jobs_completed");
    }
}

fn node_matches(n: &NodeAlloc, script: &PbsScript) -> bool {
    let cores = (n.spec.capacity.cpu_milli / 1000) as u32;
    cores >= script.ppn
        && n.spec.capacity.mem_bytes >= script.mem
        && script.properties.iter().all(|p| n.spec.has_feature(p))
}

/// Build policy NodeStates for one queue (+property filter); returns the
/// dense index → node-name mapping.
fn snapshot_nodes(
    state: &SrvState,
    queue: &QueueConfig,
    props: &[String],
) -> (Vec<NodeState>, Vec<String>) {
    let mut states = Vec::new();
    let mut names = Vec::new();
    for alloc in &state.nodes {
        let in_queue = queue.nodes.is_empty() || queue.nodes.contains(&alloc.spec.name);
        let has_props = props.iter().all(|p| alloc.spec.has_feature(p));
        if in_queue && has_props {
            let total_cores = (alloc.spec.capacity.cpu_milli / 1000) as u32;
            states.push(NodeState {
                id: names.len(),
                total_cores,
                free_cores: total_cores.saturating_sub(alloc.used_cores),
                total_mem: alloc.spec.capacity.mem_bytes,
                free_mem: alloc.spec.capacity.mem_bytes.saturating_sub(alloc.used_mem),
            });
            names.push(alloc.spec.name.clone());
        }
    }
    (states, names)
}

fn snapshot_running(state: &SrvState, index_names: &[String]) -> Vec<RunningJob> {
    let name_to_idx: HashMap<&str, usize> =
        index_names.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
    state
        .jobs
        .values()
        .filter(|j| j.state == JobState::Running)
        .map(|j| RunningJob {
            id: j.seq,
            placement: j
                .placement
                .iter()
                .filter_map(|n| name_to_idx.get(n.as_str()))
                .map(|&node| crate::sched::Placement {
                    node,
                    cores: j.script.ppn,
                    mem: j.script.mem,
                })
                .collect(),
            expected_end_s: j.start_s.unwrap_or(0.0) + j.script.walltime.as_secs_f64(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeRole, Resources};
    use crate::sched::EasyBackfill;
    use crate::singularity::{ImageRegistry, RuntimeKind};

    fn boot(n_nodes: usize, cores: u32) -> (PbsServer, Shutdown) {
        let sd = Shutdown::new();
        let (timers, _) = Timers::start(sd.clone());
        let fs = SharedFs::new();
        let runtime = Runtime::new(
            RuntimeKind::Singularity,
            ImageRegistry::with_defaults(),
            Metrics::new(),
        );
        let nodes: Vec<NodeSpec> = (0..n_nodes)
            .map(|i| {
                NodeSpec::new(
                    format!("cn{i:02}"),
                    NodeRole::TorqueCompute,
                    Resources::cores(cores, 32 << 30),
                )
            })
            .collect();
        let mut cfg = PbsConfig::default();
        cfg.time_scale = 0.001; // 1000x compressed
        cfg.sched_period = Duration::from_millis(2);
        let srv = PbsServer::start(
            cfg,
            nodes,
            runtime,
            fs,
            Box::new(EasyBackfill),
            timers,
            Metrics::new(),
            sd.clone(),
        )
        .unwrap();
        (srv, sd)
    }

    #[test]
    fn fig3_job_lifecycle() {
        let (srv, sd) = boot(2, 8);
        let id = srv
            .qsub(
                "#!/bin/sh\n#PBS -l walltime=00:30:00\n#PBS -l nodes=1\n#PBS -e $HOME/low.err\n#PBS -o $HOME/low.out\nexport PATH=$PATH:/usr/local/bin\nsingularity run lolcow_latest.sif\n",
                "user",
            )
            .unwrap();
        assert_eq!(id.server, "torque-head");
        let job = srv.wait_for(id.seq, Duration::from_secs(10)).unwrap();
        assert_eq!(job.exit_code, Some(0));
        assert!(!job.cancelled);
        let out = srv.fs().read_string("$HOME/low.out").unwrap();
        assert!(out.contains("Moo"), "lolcow output staged: {out}");
        assert!(srv.fs().exists("$HOME/low.err"));
        sd.trigger();
    }

    #[test]
    fn resources_charged_and_freed() {
        let (srv, sd) = boot(1, 8);
        let id = srv.qsub("#PBS -l nodes=1:ppn=8\nsleep 200\n", "u").unwrap();
        // wait until running
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            if srv.qstat_job(id.seq).unwrap().state == JobState::Running {
                break;
            }
            assert!(Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(srv.pbsnodes()[0].1, 8, "all cores charged");
        // A second full-node job must wait.
        let id2 = srv.qsub("#PBS -l nodes=1:ppn=8\necho hi\n", "u").unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(srv.qstat_job(id2.seq).unwrap().state, JobState::Queued);
        srv.qdel(id.seq).unwrap();
        let j2 = srv.wait_for(id2.seq, Duration::from_secs(10)).unwrap();
        assert_eq!(j2.exit_code, Some(0));
        assert_eq!(srv.pbsnodes()[0].1, 0, "cores freed");
        sd.trigger();
    }

    #[test]
    fn qdel_queued_and_running() {
        let (srv, sd) = boot(1, 4);
        let running = srv.qsub("#PBS -l nodes=1:ppn=4\nsleep 500\n", "u").unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let queued = srv.qsub("#PBS -l nodes=1:ppn=4\necho q\n", "u").unwrap();
        srv.qdel(queued.seq).unwrap();
        let jq = srv.qstat_job(queued.seq).unwrap();
        assert_eq!(jq.state, JobState::Completed);
        assert!(jq.cancelled);
        assert_eq!(jq.exit_code, Some(271));
        srv.qdel(running.seq).unwrap();
        let jr = srv.wait_for(running.seq, Duration::from_secs(10)).unwrap();
        assert!(jr.cancelled);
        assert!(srv.qdel(9999).is_err());
        sd.trigger();
    }

    #[test]
    fn hold_release_cycle() {
        let (srv, sd) = boot(1, 4);
        // Fill the node so our target job stays queued.
        let filler = srv.qsub("#PBS -l nodes=1:ppn=4\nsleep 300\n", "u").unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let id = srv.qsub("#PBS -l nodes=1:ppn=4\necho held\n", "u").unwrap();
        srv.qhold(id.seq).unwrap();
        assert_eq!(srv.qstat_job(id.seq).unwrap().state, JobState::Held);
        assert!(srv.qhold(id.seq).is_err(), "double hold");
        srv.qdel(filler.seq).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(
            srv.qstat_job(id.seq).unwrap().state,
            JobState::Held,
            "held job must not start"
        );
        srv.qrls(id.seq).unwrap();
        let j = srv.wait_for(id.seq, Duration::from_secs(10)).unwrap();
        assert_eq!(j.exit_code, Some(0));
        sd.trigger();
    }

    #[test]
    fn qalter_only_before_start() {
        let (srv, sd) = boot(1, 4);
        let filler = srv.qsub("#PBS -l nodes=1:ppn=4\nsleep 300\n", "u").unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let id = srv.qsub("#PBS -l nodes=1:ppn=1\necho x\n", "u").unwrap();
        srv.qalter(id.seq, Some(99), Some(Duration::from_secs(60))).unwrap();
        let j = srv.qstat_job(id.seq).unwrap();
        assert_eq!(j.script.priority, 99);
        assert_eq!(j.script.walltime, Duration::from_secs(60));
        assert!(srv.qalter(filler.seq, Some(1), None).is_err(), "running job");
        srv.qdel(filler.seq).unwrap();
        sd.trigger();
    }

    #[test]
    fn infeasible_job_rejected_at_submit() {
        let (srv, sd) = boot(2, 8);
        assert!(srv.qsub("#PBS -l nodes=3\necho x\n", "u").is_err(), "too many nodes");
        assert!(srv.qsub("#PBS -l nodes=1:ppn=16\necho x\n", "u").is_err(), "too wide");
        assert!(srv.qsub("#PBS -q nope\necho x\n", "u").is_err(), "unknown queue");
        sd.trigger();
    }

    #[test]
    fn walltime_exceeded_recorded() {
        let (srv, sd) = boot(1, 4);
        // walltime 5s nominal = 5ms real; job sleeps 60s nominal = 60ms real.
        let id = srv.qsub("#PBS -l walltime=0:05\nsleep 60\n", "u").unwrap();
        let j = srv.wait_for(id.seq, Duration::from_secs(10)).unwrap();
        assert!(j.walltime_exceeded, "{j:?}");
        assert_eq!(j.exit_code, Some(137));
        sd.trigger();
    }

    #[test]
    fn accounting_written() {
        let (srv, sd) = boot(2, 8);
        let a = srv.qsub("#PBS -N a\necho a\n", "alice").unwrap();
        let b = srv.qsub("#PBS -N b\necho b\n", "bob").unwrap();
        srv.wait_for(a.seq, Duration::from_secs(10)).unwrap();
        srv.wait_for(b.seq, Duration::from_secs(10)).unwrap();
        let acct = srv.accounting();
        assert_eq!(acct.len(), 2);
        assert!(acct.iter().any(|r| r.user == "alice"));
        assert!(acct.iter().all(|r| r.end_s >= r.start_s && r.start_s >= r.submit_s));
        sd.trigger();
    }

    #[test]
    fn multi_node_job_charges_all_chunks() {
        let (srv, sd) = boot(3, 4);
        let id = srv.qsub("#PBS -l nodes=2:ppn=4\nsleep 100\n", "u").unwrap();
        std::thread::sleep(Duration::from_millis(40));
        let used: u32 = srv.pbsnodes().iter().map(|(_, u, _)| *u).sum();
        assert_eq!(used, 8, "two chunks of 4 cores");
        let j = srv.qstat_job(id.seq).unwrap();
        assert_eq!(j.placement.len(), 2);
        srv.qdel(id.seq).unwrap();
        srv.wait_for(id.seq, Duration::from_secs(10)).unwrap();
        sd.trigger();
    }
}
