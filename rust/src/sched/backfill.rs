//! EASY backfill (Lifka 1995) — the production discipline of Torque+Maui
//! and Slurm's `sched/backfill`.
//!
//! Head-of-queue job blocked? Compute its *shadow time* (earliest instant
//! enough capacity frees up, from running jobs' expected ends), reserve the
//! capacity, then let later jobs jump the queue **only if** they cannot
//! delay the reservation: either they finish before the shadow time, or
//! they use only capacity the reserved job won't need ("extra" nodes).

use super::policy::{
    queue_order, try_place, Assignment, NodeState, PendingJob, RunningJob, SchedPolicy,
};

pub struct EasyBackfill;

impl SchedPolicy for EasyBackfill {
    fn name(&self) -> &'static str {
        "easy-backfill"
    }

    fn schedule(
        &self,
        now_s: f64,
        pending: &[PendingJob],
        nodes: &[NodeState],
        running: &[RunningJob],
    ) -> Vec<Assignment> {
        let mut queue: Vec<&PendingJob> = pending.iter().collect();
        queue.sort_by(|a, b| queue_order(a, b));
        let mut free: Vec<NodeState> = nodes.to_vec();
        let mut out = Vec::new();

        // Phase 1: start queue-order jobs while they fit.
        let mut idx = 0;
        while idx < queue.len() {
            match try_place(queue[idx], &mut free) {
                Some(placement) => {
                    out.push(Assignment { job: queue[idx].id, placement });
                    idx += 1;
                }
                None => break,
            }
        }
        if idx >= queue.len() {
            return out;
        }

        // Phase 2: reservation for the blocked head `queue[idx]`.
        let head = queue[idx];
        let reservation = compute_reservation(head, now_s, &free, running);

        // Phase 3: backfill the remainder.
        for job in &queue[idx + 1..] {
            // Candidate must fit right now.
            let mut trial = free.clone();
            let placement = match try_place(job, &mut trial) {
                Some(p) => p,
                None => continue,
            };
            let ok = match &reservation {
                None => true, // head can never run (bigger than the machine)
                Some(res) => {
                    let ends_before_shadow =
                        now_s + job.walltime.as_secs_f64() <= res.shadow_s + 1e-9;
                    let avoids_reserved =
                        placement.iter().all(|p| !res.nodes.contains(&p.node));
                    ends_before_shadow || avoids_reserved
                }
            };
            if ok {
                free = trial;
                out.push(Assignment { job: job.id, placement });
            }
        }
        out
    }
}

struct Reservation {
    /// Earliest time the head job can start.
    shadow_s: f64,
    /// Nodes the head job will occupy at the shadow time.
    nodes: Vec<usize>,
}

/// Simulate node releases in expected-end order until the head job fits.
fn compute_reservation(
    head: &PendingJob,
    now_s: f64,
    free_now: &[NodeState],
    running: &[RunningJob],
) -> Option<Reservation> {
    let mut future: Vec<NodeState> = free_now.to_vec();
    // Releases sorted by time.
    let mut releases: Vec<(f64, usize, u32, u64)> = running
        .iter()
        .flat_map(|r| {
            r.placement
                .iter()
                .map(move |p| (r.expected_end_s.max(now_s), p.node, p.cores, p.mem))
        })
        .collect();
    releases.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    // Try at `now`, then after each release.
    let check = |future: &mut Vec<NodeState>, t: f64| -> Option<Reservation> {
        let mut trial = future.clone();
        try_place(head, &mut trial).map(|placement| Reservation {
            shadow_s: t,
            nodes: placement.iter().map(|p| p.node).collect(),
        })
    };
    if let Some(r) = check(&mut future, now_s) {
        return Some(r); // shouldn't happen (head was blocked) but harmless
    }
    let mut i = 0;
    while i < releases.len() {
        let t = releases[i].0;
        // apply all releases at time t
        while i < releases.len() && (releases[i].0 - t).abs() < 1e-9 {
            let (_, node, cores, mem) = releases[i];
            if let Some(n) = future.iter_mut().find(|n| n.id == node) {
                n.free_cores = (n.free_cores + cores).min(n.total_cores);
                n.free_mem = (n.free_mem + mem).min(n.total_mem);
            }
            i += 1;
        }
        if let Some(r) = check(&mut future, t) {
            return Some(r);
        }
    }
    None // head never fits even on an empty machine
}

#[cfg(test)]
mod tests {
    use super::*;
    

    fn nodes(n: usize, cores: u32) -> Vec<NodeState> {
        (0..n).map(|i| NodeState::whole(i, cores, 64 << 30)).collect()
    }

    fn job(id: u64, n: u32, ppn: u32, wall_s: u64, submit: f64) -> PendingJob {
        let mut j = PendingJob::simple(id, n, ppn, wall_s);
        j.submit_s = submit;
        j
    }

    /// 2 nodes; node 0 busy until t=100. Head needs both nodes.
    /// A short job (ends before 100) backfills; a long one must not.
    #[test]
    fn backfills_short_job_under_reservation() {
        let running = vec![RunningJob {
            id: 99,
            placement: vec![super::super::policy::Placement { node: 0, cores: 8, mem: 0 }],
            expected_end_s: 100.0,
        }];
        let mut ns = nodes(2, 8);
        ns[0].free_cores = 0;
        let pending = vec![
            job(1, 2, 8, 50, 0.0),  // head: needs both nodes -> blocked
            job(2, 1, 8, 50, 1.0),  // short: 0+50 <= 100 -> backfills on node 1
            job(3, 1, 8, 500, 2.0), // long: would delay head -> no
        ];
        let out = EasyBackfill.schedule(0.0, &pending, &ns, &running);
        let ids: Vec<u64> = out.iter().map(|a| a.job).collect();
        assert_eq!(ids, vec![2]);
        assert_eq!(out[0].placement[0].node, 1);
    }

    #[test]
    fn long_job_backfills_on_extra_nodes() {
        // 3 nodes; node 0 busy till 100; head needs 2 nodes => reserved
        // {1,2}? No: at shadow time all of {0,1,2} free; reservation picks
        // first-fit {0,1}; node 2 is extra => a long 1-node job may run there.
        let running = vec![RunningJob {
            id: 99,
            placement: vec![super::super::policy::Placement { node: 0, cores: 8, mem: 0 }],
            expected_end_s: 100.0,
        }];
        let mut ns = nodes(3, 8);
        ns[0].free_cores = 0;
        // head needs 3 nodes -> blocked until node 0 frees; reserved {0,1,2}.
        let pending = vec![job(1, 3, 8, 50, 0.0), job(2, 1, 8, 500, 1.0)];
        let out = EasyBackfill.schedule(0.0, &pending, &ns, &running);
        assert!(out.is_empty(), "no extra node: reservation covers all nodes");

        // head needs only 2 nodes -> reservation {0,1}; node 2 is extra.
        let pending = vec![job(1, 2, 8, 50, 0.0), job(2, 1, 8, 500, 1.0)];
        let mut ns = nodes(3, 8);
        ns[0].free_cores = 0;
        // head fits NOW on {1,2}… so it is not blocked. Fill node 2 too.
        ns[2].free_cores = 0;
        let running2 = vec![
            running[0].clone(),
            RunningJob {
                id: 98,
                placement: vec![super::super::policy::Placement {
                    node: 2,
                    cores: 8,
                    mem: 0,
                }],
                expected_end_s: 200.0,
            },
        ];
        let out = EasyBackfill.schedule(0.0, &pending, &ns, &running2);
        // shadow: node 0 frees at 100 -> head fits on {0,1} at t=100.
        // job 2 (500s) cannot finish by 100 but node… 1 is reserved; only
        // node 1 is free now and it IS reserved -> nothing backfills.
        assert!(out.is_empty());
    }

    /// Shadow-time boundary: a backfill candidate ending *exactly* at the
    /// shadow time cannot delay the reservation and must be admitted; one
    /// second longer must be rejected (it would land on a reserved node).
    #[test]
    fn job_exactly_at_shadow_time_backfills() {
        let running = vec![RunningJob {
            id: 99,
            placement: vec![super::super::policy::Placement { node: 0, cores: 8, mem: 0 }],
            expected_end_s: 100.0,
        }];
        let mut ns = nodes(2, 8);
        ns[0].free_cores = 0;
        // Head needs both nodes -> blocked; shadow = 100, reserved {0,1}.
        let head = job(1, 2, 8, 50, 0.0);
        let exact = job(2, 1, 8, 100, 1.0); // ends at 0 + 100 == shadow
        let out = EasyBackfill.schedule(0.0, &[head.clone(), exact], &ns, &running);
        assert_eq!(out.len(), 1, "walltime == shadow gap is admissible");
        assert_eq!(out[0].job, 2);

        let too_long = job(3, 1, 8, 101, 1.0); // ends at 101 > shadow
        let out = EasyBackfill.schedule(0.0, &[head, too_long], &ns, &running);
        assert!(out.is_empty(), "one second past the shadow time must be rejected");
    }

    /// A long job whose placement avoids every reserved node runs on the
    /// "extra" capacity even though it outlives the shadow time.
    #[test]
    fn long_job_runs_on_extra_nodes() {
        // Nodes 0 and 1 busy till 100; head needs 2 -> blocked (only node
        // 2 free). At shadow=100 the reservation first-fits {0,1}, so
        // node 2 is extra: a 500s 1-node job may take it now.
        let running = vec![
            RunningJob {
                id: 90,
                placement: vec![super::super::policy::Placement { node: 0, cores: 8, mem: 0 }],
                expected_end_s: 100.0,
            },
            RunningJob {
                id: 91,
                placement: vec![super::super::policy::Placement { node: 1, cores: 8, mem: 0 }],
                expected_end_s: 100.0,
            },
        ];
        let mut ns = nodes(3, 8);
        ns[0].free_cores = 0;
        ns[1].free_cores = 0;
        let pending = vec![job(1, 2, 8, 50, 0.0), job(2, 1, 8, 500, 1.0)];
        let out = EasyBackfill.schedule(0.0, &pending, &ns, &running);
        assert_eq!(out.len(), 1, "long job admitted on the extra node");
        assert_eq!(out[0].job, 2);
        assert_eq!(out[0].placement[0].node, 2, "placed outside the reservation");
    }

    /// Zero-walltime jobs trivially end before any shadow time: they
    /// backfill freely even onto reserved nodes, and never delay the head.
    #[test]
    fn zero_runtime_jobs_backfill_freely() {
        let running = vec![RunningJob {
            id: 99,
            placement: vec![super::super::policy::Placement { node: 0, cores: 8, mem: 0 }],
            expected_end_s: 100.0,
        }];
        let mut ns = nodes(2, 8);
        ns[0].free_cores = 0;
        // Head blocked (needs both nodes); two zero-walltime jobs behind
        // it — the first fills node 1, the second no longer fits *now*.
        let pending = vec![
            job(1, 2, 8, 50, 0.0),
            job(2, 1, 8, 0, 1.0),
            job(3, 1, 4, 0, 2.0),
        ];
        let out = EasyBackfill.schedule(0.0, &pending, &ns, &running);
        let ids: Vec<u64> = out.iter().map(|a| a.job).collect();
        assert_eq!(ids, vec![2], "zero-walltime backfills on the reserved node");
        // With free cores remaining, both zero-walltime jobs go.
        let pending = vec![job(1, 2, 8, 50, 0.0), job(2, 1, 4, 0, 1.0), job(3, 1, 4, 0, 2.0)];
        let out = EasyBackfill.schedule(0.0, &pending, &ns, &running);
        let ids: Vec<u64> = out.iter().map(|a| a.job).collect();
        assert_eq!(ids, vec![2, 3]);
    }

    #[test]
    fn head_placed_when_it_fits() {
        let pending = vec![job(1, 2, 4, 60, 0.0), job(2, 1, 4, 60, 1.0)];
        let out = EasyBackfill.schedule(0.0, &pending, &nodes(2, 8), &[]);
        assert_eq!(out.len(), 2, "both fit immediately");
    }

    #[test]
    fn impossible_head_does_not_block_backfill() {
        // Head asks for more nodes than exist: EASY lets everything else run.
        let pending = vec![job(1, 10, 8, 60, 0.0), job(2, 1, 8, 9999, 1.0)];
        let out = EasyBackfill.schedule(0.0, &pending, &nodes(2, 8), &[]);
        let ids: Vec<u64> = out.iter().map(|a| a.job).collect();
        assert_eq!(ids, vec![2]);
    }

    #[test]
    fn backfill_beats_fifo_on_utilization() {
        // The E1 shape in miniature: FIFO leaves node 1 idle, EASY fills it.
        let running = vec![RunningJob {
            id: 99,
            placement: vec![super::super::policy::Placement { node: 0, cores: 8, mem: 0 }],
            expected_end_s: 100.0,
        }];
        let mut ns = nodes(2, 8);
        ns[0].free_cores = 0;
        let pending = vec![job(1, 2, 8, 50, 0.0), job(2, 1, 8, 50, 1.0)];
        let fifo = super::super::policy::FifoPolicy.schedule(0.0, &pending, &ns, &running);
        let easy = EasyBackfill.schedule(0.0, &pending, &ns, &running);
        assert!(fifo.is_empty());
        assert_eq!(easy.len(), 1);
    }

    #[test]
    fn reservation_uses_expected_ends_in_order() {
        // nodes 0,1 busy until 50 and 100; head needs 2 idle+1 => shadow
        // must be 100 (when both free), so a 60s backfill (ends at 60 <=100)
        // is allowed on the idle node 2… wait head needs 3 nodes: {2} free.
        let running = vec![
            RunningJob {
                id: 90,
                placement: vec![super::super::policy::Placement { node: 0, cores: 8, mem: 0 }],
                expected_end_s: 50.0,
            },
            RunningJob {
                id: 91,
                placement: vec![super::super::policy::Placement { node: 1, cores: 8, mem: 0 }],
                expected_end_s: 100.0,
            },
        ];
        let mut ns = nodes(3, 8);
        ns[0].free_cores = 0;
        ns[1].free_cores = 0;
        let pending = vec![job(1, 3, 8, 10, 0.0), job(2, 1, 8, 60, 1.0)];
        let out = EasyBackfill.schedule(0.0, &pending, &ns, &running);
        // shadow = 100; job 2 ends at 60 <= 100 -> backfills on node 2.
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].job, 2);

        // A 150s job would delay the head (ends 150 > 100) and node 2 is
        // reserved at shadow time -> rejected.
        let pending = vec![job(1, 3, 8, 10, 0.0), job(3, 1, 8, 150, 1.0)];
        let out = EasyBackfill.schedule(0.0, &pending, &ns, &running);
        assert!(out.is_empty());
    }
}
