//! The unified resource API: one client trait over every transport, plus
//! typed per-kind handles.
//!
//! [`ApiClient`] is the full verb set (create/get/update/update_status/
//! patch/delete/list/watch) implemented by both the in-process
//! [`super::ApiServer`] and the socket-backed [`super::RemoteApi`], so
//! controllers, the operator, and the CLI are written once and run against
//! either transport. [`Api<K>`] wraps an `Arc<dyn ApiClient>` with a
//! [`ResourceView`] so callers get `PodView`/`NodeView`/`WlmJobView` back
//! instead of raw [`KubeObject`] trees — the kube-rs `Api<K>` shape.

use super::api::{
    pdb_blocking, requeue_evict_mutation, KubeObject, KIND_POD, KIND_PODDISRUPTIONBUDGET,
};
use super::store::WatchEvent;
use crate::encoding::{decode_str_map, encode_str_map, Value};
use crate::util::{Error, Result};
use std::marker::PhantomData;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// List filters, mirroring the k8s list API: label selectors, field
/// selectors over the encoded object tree (`spec.nodeName`,
/// `status.phase`, `metadata.name`, ...), a minimum resourceVersion
/// (the `resourceVersionMatch=NotOlderThan` contract — the store always
/// serves the latest state, so the only meaningful check is freshness),
/// and paging (`limit` + the `continue` cursor from the previous page).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ListOptions {
    pub label_selector: Vec<(String, String)>,
    pub field_selector: Vec<(String, String)>,
    pub min_resource_version: Option<u64>,
    /// Page size; 0/None = everything in one response.
    pub limit: Option<usize>,
    /// Resume cursor: the `continue_token` of the previous page. Unlike
    /// real k8s (which pins a snapshot), pages walk the *live* store in
    /// name order — items created behind the cursor are missed until the
    /// next full relist, the same freshness contract as
    /// `min_resource_version`.
    pub continue_token: Option<String>,
    /// Delta floor (PR 6): ask the server to ship only what changed
    /// *after* this version — changed objects as items plus deleted names
    /// ([`ObjectList::deleted`]) — instead of the full set. Best-effort:
    /// when the server's retained history no longer covers the floor it
    /// answers a normal full list; check [`ObjectList::delta`] to know
    /// which you got. Intended for unfiltered cache resyncs (the
    /// reflector's 410 recovery); `limit`/`continue` are ignored in delta
    /// mode and selectors filter only the changed items.
    pub delta_floor: Option<u64>,
}

impl ListOptions {
    /// No filtering (list everything of the kind).
    pub fn all() -> ListOptions {
        ListOptions::default()
    }

    pub fn with_label(mut self, key: &str, val: &str) -> ListOptions {
        self.label_selector.push((key.to_string(), val.to_string()));
        self
    }

    pub fn with_field(mut self, path: &str, val: &str) -> ListOptions {
        self.field_selector.push((path.to_string(), val.to_string()));
        self
    }

    pub fn not_older_than(mut self, version: u64) -> ListOptions {
        self.min_resource_version = Some(version);
        self
    }

    /// Page size for paged lists.
    pub fn with_limit(mut self, limit: usize) -> ListOptions {
        self.limit = Some(limit);
        self
    }

    /// Resume after the given cursor (an [`ObjectList::continue_token`]).
    pub fn continue_from(mut self, token: &str) -> ListOptions {
        self.continue_token = Some(token.to_string());
        self
    }

    /// Ask for a delta list: only events after `version` (see
    /// [`ListOptions::delta_floor`]).
    pub fn delta_since(mut self, version: u64) -> ListOptions {
        self.delta_floor = Some(version);
        self
    }

    /// Parse a kubectl-style selector string: `key=value,key2=value2`.
    pub fn parse_selector(s: &str) -> Result<Vec<(String, String)>> {
        s.split(',')
            .filter(|p| !p.trim().is_empty())
            .map(|pair| {
                pair.split_once('=')
                    .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
                    .ok_or_else(|| {
                        Error::parse(format!("bad selector `{pair}` (want key=value)"))
                    })
            })
            .collect()
    }

    /// Does `obj` pass both selectors?
    pub fn matches(&self, obj: &KubeObject) -> bool {
        self.label_selector
            .iter()
            .all(|(k, v)| obj.meta.label(k) == Some(v.as_str()))
            && self.matches_fields(obj)
    }

    /// Field-selector match. Supported roots: `spec.*` and `status.*`
    /// (walked directly through the dynamic tree — no re-encode of the
    /// object on this per-list hot path), plus `metadata.name`,
    /// `metadata.uid`, `metadata.resourceVersion`, and
    /// `metadata.labels.<key>`. Strings compare verbatim; other scalars
    /// compare through their compact-JSON rendering (`metadata.uid=3`).
    pub fn matches_fields(&self, obj: &KubeObject) -> bool {
        self.field_selector.iter().all(|(path, want)| field_matches(obj, path, want))
    }

    /// Wire encoding for the RPC transport.
    pub fn to_value(&self) -> Value {
        let mut v = Value::map();
        if !self.label_selector.is_empty() {
            v.insert("labelSelector", encode_str_map(&self.label_selector));
        }
        if !self.field_selector.is_empty() {
            v.insert("fieldSelector", encode_str_map(&self.field_selector));
        }
        if let Some(rv) = self.min_resource_version {
            v.insert("minResourceVersion", rv);
        }
        if let Some(limit) = self.limit {
            v.insert("limit", limit as u64);
        }
        if let Some(token) = &self.continue_token {
            v.insert("continue", token.clone());
        }
        if let Some(floor) = self.delta_floor {
            v.insert("deltaFrom", floor);
        }
        v
    }

    pub fn from_value(v: &Value) -> ListOptions {
        ListOptions {
            label_selector: v.get("labelSelector").map(decode_str_map).unwrap_or_default(),
            field_selector: v.get("fieldSelector").map(decode_str_map).unwrap_or_default(),
            min_resource_version: v.opt_int("minResourceVersion").map(|i| i as u64),
            limit: v.opt_int("limit").map(|i| i as usize),
            continue_token: v.opt_str("continue").map(String::from),
            delta_floor: v.opt_int("deltaFrom").map(|i| i as u64),
        }
    }
}

fn value_matches(v: Option<&Value>, want: &str) -> bool {
    match v {
        Some(Value::Str(s)) => s == want,
        Some(other) => other.to_string() == want,
        None => false,
    }
}

fn field_matches(obj: &KubeObject, path: &str, want: &str) -> bool {
    let (root, rest) = path.split_once('.').unwrap_or((path, ""));
    match root {
        "spec" | "status" => {
            let tree = if root == "spec" { &obj.spec } else { &obj.status };
            if rest.is_empty() {
                return value_matches(Some(tree), want);
            }
            let parts: Vec<&str> = rest.split('.').collect();
            value_matches(tree.path(&parts), want)
        }
        "metadata" => match rest {
            "name" => obj.meta.name == want,
            "uid" => obj.meta.uid.to_string() == want,
            "resourceVersion" => obj.meta.resource_version.to_string() == want,
            _ => rest
                .strip_prefix("labels.")
                .map(|k| obj.meta.label(k) == Some(want))
                .unwrap_or(false),
        },
        "kind" => obj.kind == want,
        "apiVersion" => obj.api_version == want,
        _ => false,
    }
}

/// A list response: items plus the server clock (drives AGE columns) and
/// the store version the list was served at (the watch bookmark).
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectList {
    pub server_s: f64,
    pub resource_version: u64,
    pub items: Vec<KubeObject>,
    /// Set when a `limit` truncated the result: pass it back via
    /// [`ListOptions::continue_from`] for the next page. `None` = final
    /// (or only) page.
    pub continue_token: Option<String>,
    /// True when the server answered a [`ListOptions::delta_since`]
    /// request from its retained history: `items` holds only objects
    /// changed after the floor, `deleted` the names removed since it.
    /// False = a normal full list (including delta requests the server
    /// could not serve as deltas).
    pub delta: bool,
    /// Names deleted since the delta floor (delta responses only).
    pub deleted: Vec<String>,
}

impl ObjectList {
    /// A full (non-delta) list response.
    pub fn full(
        server_s: f64,
        resource_version: u64,
        items: Vec<KubeObject>,
        continue_token: Option<String>,
    ) -> ObjectList {
        ObjectList {
            server_s,
            resource_version,
            items,
            continue_token,
            delta: false,
            deleted: Vec::new(),
        }
    }
}

/// One item of a batched status update (PR 9): a merge patch against
/// `(kind, name)` — the server-shippable form of what an
/// [`ApiClient::update_status`] closure does in-process (closures cannot
/// cross the socket). Built by the scheduler's bind batch; applied with
/// [`ApiClient::update_status_batch`].
#[derive(Debug, Clone, PartialEq)]
pub struct BatchPatchItem {
    pub kind: String,
    pub name: String,
    pub patch: Value,
}

impl BatchPatchItem {
    pub fn new(kind: &str, name: &str, patch: Value) -> BatchPatchItem {
        BatchPatchItem { kind: kind.to_string(), name: name.to_string(), patch }
    }

    /// Wire encoding for the `UpdateStatusBatch` RPC verb.
    pub fn to_value(&self) -> Value {
        Value::map()
            .with("kind", self.kind.clone())
            .with("name", self.name.clone())
            .with("patch", self.patch.clone())
    }

    pub fn from_value(v: &Value) -> Result<BatchPatchItem> {
        Ok(BatchPatchItem {
            kind: v.req_str("kind")?.to_string(),
            name: v.req_str("name")?.to_string(),
            patch: v.get("patch").cloned().unwrap_or_else(Value::map),
        })
    }
}

/// What an eviction does to the pod once its PodDisruptionBudgets allow
/// the disruption. Real Kubernetes only deletes; the requeue mode is the
/// HPC twist — quota preemption (kueue) wants the pod *unbound and
/// re-gated*, not gone, and doing it inside the eviction keeps the
/// unbind + gate atomic so the scheduler can never re-bind in between.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EvictionMode {
    /// Delete the pod (the `pods/eviction` subresource semantics —
    /// cluster-autoscaler drains and chaos kills use this).
    Delete,
    /// Unbind the pod, reset it to Pending, and park it behind the named
    /// scheduling gate for re-admission (kueue preemption).
    Requeue { gate: String },
}

impl EvictionMode {
    /// Wire encoding for the `kube.Api/Evict` RPC body.
    pub fn to_value(&self) -> Value {
        match self {
            EvictionMode::Delete => Value::map().with("mode", "Delete"),
            EvictionMode::Requeue { gate } => {
                Value::map().with("mode", "Requeue").with("gate", gate.clone())
            }
        }
    }

    pub fn from_value(v: &Value) -> Result<EvictionMode> {
        match v.opt_str("mode").unwrap_or("Delete") {
            "Delete" => Ok(EvictionMode::Delete),
            "Requeue" => Ok(EvictionMode::Requeue {
                gate: v
                    .opt_str("gate")
                    .filter(|g| !g.is_empty())
                    .ok_or_else(|| Error::parse("Requeue eviction needs a gate"))?
                    .to_string(),
            }),
            other => Err(Error::parse(format!("unknown eviction mode `{other}`"))),
        }
    }
}

/// The unified resource-API surface. Object-safe by design: controllers
/// hold `Arc<dyn ApiClient>` and never know whether they talk to the
/// in-process store or a red-box socket.
pub trait ApiClient: Send + Sync {
    fn create(&self, obj: KubeObject) -> Result<KubeObject>;
    fn get(&self, kind: &str, name: &str) -> Result<KubeObject>;
    /// Full update with optimistic concurrency (object must carry the
    /// current resourceVersion).
    fn update(&self, obj: KubeObject) -> Result<KubeObject>;
    /// Status-subresource update with bounded retry-on-conflict: fetch the
    /// latest object, apply `f`, commit; retried until it lands. Returns
    /// [`crate::util::ApiError::ConflictExhausted`] if contention never
    /// lets the write through.
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject>;
    /// JSON-merge-patch over `spec`/`status`/`metadata.labels`/
    /// `metadata.annotations`: maps merge recursively, `null` deletes a
    /// key, everything else replaces. Retried on conflict like
    /// [`ApiClient::update_status`].
    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject>;
    /// Batched status updates (PR 9): apply each item's merge patch,
    /// returning one typed result per item in input order — a failure on
    /// one item never poisons the rest. The outer `Result` is
    /// transport-level only (nothing applied). The in-process
    /// [`super::ApiServer`] commits the whole batch under one
    /// global-lock section (no conflict window at all); the socket-backed
    /// [`super::RemoteApi`] ships it as a single `UpdateStatusBatch` RPC
    /// — one red-box round trip for N writes. The default implementation
    /// degrades to one [`ApiClient::patch_merge`] per item so decorators
    /// and test wrappers stay correct without overriding.
    fn update_status_batch(
        &self,
        items: &[BatchPatchItem],
    ) -> Result<Vec<Result<KubeObject>>> {
        Ok(items.iter().map(|it| self.patch_merge(&it.kind, &it.name, &it.patch)).collect())
    }
    /// Delete, cascading transitively through owner references.
    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject>;
    /// The `pods/eviction` subresource: the *polite* disruption path every
    /// drain/preemption/chaos kill must take instead of a raw delete. The
    /// server checks the pod against every matching `policy/v1`
    /// PodDisruptionBudget first; a disruption the budgets cannot absorb
    /// returns the typed 429-style
    /// [`crate::util::ApiError::DisruptionBudgetExceeded`] (retry a later
    /// cycle) and leaves the pod untouched. The default implementation
    /// composes the check from `get`/`list` plus `delete`/`update_status`
    /// so decorators and test wrappers stay correct without overriding;
    /// [`super::ApiServer`] overrides it with the authoritative
    /// server-side check, and [`super::RemoteApi`] ships it as one
    /// `kube.Api/Evict` RPC.
    fn evict(&self, name: &str, mode: &EvictionMode) -> Result<KubeObject> {
        let victim = self.get(KIND_POD, name)?;
        let pods = self.list(KIND_POD, &ListOptions::all())?.items;
        let pdbs = self.list(KIND_PODDISRUPTIONBUDGET, &ListOptions::all())?.items;
        if let Some(budget) = pdb_blocking(&pdbs, &pods, &victim) {
            return Err(Error::disruption_budget_exceeded(KIND_POD, name, budget));
        }
        match mode {
            EvictionMode::Delete => self.delete(KIND_POD, name),
            EvictionMode::Requeue { gate } => {
                let gate = gate.clone();
                self.update_status(KIND_POD, name, &move |o| requeue_evict_mutation(o, &gate))
            }
        }
    }
    /// `kubectl apply`: create, or — when the object exists — replace its
    /// spec, labels, and annotations wholesale while preserving status and
    /// identity (uid, creation time). For a partial update use
    /// [`ApiClient::patch_merge`].
    fn apply(&self, obj: KubeObject) -> Result<KubeObject>;
    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList>;
    /// Watch events for `kind` (None = all kinds) from `from_version`
    /// (exclusive). Both transports replay retained history first, then
    /// stream live events. A bookmark that has fallen out of the retained
    /// history window gets a stream that ends immediately — the 410-Gone
    /// signal of the k8s watch API — so consumers must relist + rewatch on
    /// stream end (see `ControllerRunner` for the canonical loop).
    fn watch(&self, kind: Option<&str>, from_version: u64) -> Result<Receiver<WatchEvent>>;
    /// Server-side seconds since cluster epoch (AGE columns).
    fn server_time_s(&self) -> Result<f64>;
}

/// A client decorator that pins a fixed audit actor around every call
/// (PR 8): `ActorClient::wrap(client, "kube-scheduler")` makes every
/// write through the handle audit as that component, on whatever thread
/// it runs — the belt-and-braces alternative to pinning
/// [`crate::obs::push_actor`] at the top of each control cycle.
pub struct ActorClient {
    inner: Arc<dyn ApiClient>,
    actor: String,
}

impl ActorClient {
    pub fn wrap(inner: Arc<dyn ApiClient>, actor: &str) -> Arc<dyn ApiClient> {
        Arc::new(ActorClient { inner, actor: actor.to_string() })
    }
}

impl ApiClient for ActorClient {
    fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.create(obj)
    }
    fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.get(kind, name)
    }
    fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.update(obj)
    }
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.update_status(kind, name, f)
    }
    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.patch_merge(kind, name, patch)
    }
    fn update_status_batch(
        &self,
        items: &[BatchPatchItem],
    ) -> Result<Vec<Result<KubeObject>>> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.update_status_batch(items)
    }
    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.delete(kind, name)
    }
    fn evict(&self, name: &str, mode: &EvictionMode) -> Result<KubeObject> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.evict(name, mode)
    }
    fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.apply(obj)
    }
    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.list(kind, opts)
    }
    fn watch(&self, kind: Option<&str>, from_version: u64) -> Result<Receiver<WatchEvent>> {
        let _a = crate::obs::push_actor(&self.actor);
        self.inner.watch(kind, from_version)
    }
    fn server_time_s(&self) -> Result<f64> {
        self.inner.server_time_s()
    }
}

/// A typed view over one (or a family of) object kind(s). Implementors
/// decode the dynamic tree into a struct; `Api<K>` uses this to give
/// callers typed results.
pub trait ResourceView: Sized {
    /// Kinds this view decodes. The first entry is the default kind for
    /// [`Api::new`]; families (`WlmJobView` covers TorqueJob and SlurmJob)
    /// list every member and pick one with [`Api::of_kind`].
    fn kinds() -> &'static [&'static str];
    fn from_object(obj: &KubeObject) -> Result<Self>;
}

/// A typed handle for one kind over any [`ApiClient`] — `Api<PodView>`
/// against the in-process server and against a red-box socket behave
/// identically.
pub struct Api<K: ResourceView> {
    client: Arc<dyn ApiClient>,
    kind: &'static str,
    _view: PhantomData<fn() -> K>,
}

impl<K: ResourceView> Clone for Api<K> {
    fn clone(&self) -> Self {
        Api { client: self.client.clone(), kind: self.kind, _view: PhantomData }
    }
}

impl<K: ResourceView> Api<K> {
    /// Handle for the view's default kind.
    pub fn new(client: Arc<dyn ApiClient>) -> Api<K> {
        Api { client, kind: K::kinds()[0], _view: PhantomData }
    }

    /// Handle for a specific member of a view family (e.g.
    /// `Api::<WlmJobView>::of_kind(client, KIND_SLURMJOB)`).
    pub fn of_kind(client: Arc<dyn ApiClient>, kind: &str) -> Result<Api<K>> {
        let k = K::kinds().iter().copied().find(|k| *k == kind).ok_or_else(|| {
            Error::config(format!(
                "view does not cover kind `{kind}` (covers {:?})",
                K::kinds()
            ))
        })?;
        Ok(Api { client, kind: k, _view: PhantomData })
    }

    pub fn kind(&self) -> &'static str {
        self.kind
    }

    pub fn client(&self) -> &Arc<dyn ApiClient> {
        &self.client
    }

    /// Create a pre-built object of this kind; returns the typed view of
    /// the stored object.
    pub fn create(&self, obj: KubeObject) -> Result<K> {
        if obj.kind != self.kind {
            return Err(Error::Api(crate::util::ApiError::Invalid(format!(
                "Api<{}> cannot create a `{}`",
                self.kind, obj.kind
            ))));
        }
        K::from_object(&self.client.create(obj)?)
    }

    pub fn get(&self, name: &str) -> Result<K> {
        K::from_object(&self.client.get(self.kind, name)?)
    }

    /// The raw dynamic object (for fields the view does not carry).
    pub fn get_raw(&self, name: &str) -> Result<KubeObject> {
        self.client.get(self.kind, name)
    }

    /// List as typed views. Objects that fail to decode are skipped — the
    /// store accepts arbitrary shapes (hand-applied manifests), and one
    /// malformed object must not poison every typed list of the kind.
    /// Transport errors still propagate.
    pub fn list(&self, opts: &ListOptions) -> Result<Vec<K>> {
        Ok(self
            .list_raw(opts)?
            .items
            .iter()
            .filter_map(|o| K::from_object(o).ok())
            .collect())
    }

    pub fn list_raw(&self, opts: &ListOptions) -> Result<ObjectList> {
        self.client.list(self.kind, opts)
    }

    pub fn update_status(&self, name: &str, f: &dyn Fn(&mut KubeObject)) -> Result<K> {
        K::from_object(&self.client.update_status(self.kind, name, f)?)
    }

    pub fn patch_merge(&self, name: &str, patch: &Value) -> Result<K> {
        K::from_object(&self.client.patch_merge(self.kind, name, patch)?)
    }

    pub fn delete(&self, name: &str) -> Result<()> {
        self.client.delete(self.kind, name).map(|_| ())
    }

    /// Evict a pod through the `pods/eviction` subresource (see
    /// [`ApiClient::evict`]); only meaningful on `Api<PodView>`.
    pub fn evict(&self, name: &str, mode: &EvictionMode) -> Result<K> {
        if self.kind != KIND_POD {
            return Err(Error::Api(crate::util::ApiError::Invalid(format!(
                "eviction is a pods subresource (this is Api<{}>)",
                self.kind
            ))));
        }
        K::from_object(&self.client.evict(name, mode)?)
    }

    pub fn watch(&self, from_version: u64) -> Result<Receiver<WatchEvent>> {
        self.client.watch(Some(self.kind), from_version)
    }

    pub fn server_time_s(&self) -> Result<f64> {
        self.client.server_time_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kube::api::{PodView, KIND_POD};

    #[test]
    fn selector_parsing() {
        assert_eq!(
            ListOptions::parse_selector("app=web, tier=db").unwrap(),
            vec![
                ("app".to_string(), "web".to_string()),
                ("tier".to_string(), "db".to_string())
            ]
        );
        assert_eq!(ListOptions::parse_selector("").unwrap(), vec![]);
        assert!(ListOptions::parse_selector("nonsense").is_err());
    }

    #[test]
    fn field_selector_matches_encoded_paths() {
        let mut pod = PodView::build("p", "img.sif", crate::cluster::Resources::ZERO, &[]);
        pod.spec.insert("nodeName", "w1");
        pod.status.insert("phase", "Running");
        let opts = ListOptions::all()
            .with_field("spec.nodeName", "w1")
            .with_field("status.phase", "Running")
            .with_field("metadata.name", "p");
        assert!(opts.matches(&pod));
        assert!(!ListOptions::all().with_field("spec.nodeName", "w2").matches(&pod));
        assert!(!ListOptions::all().with_field("spec.missing", "x").matches(&pod));
        // Non-string scalars compare via JSON rendering.
        assert!(ListOptions::all().with_field("metadata.uid", "0").matches(&pod));
        // metadata.labels.<key> and kind are addressable too.
        let mut labelled = pod.clone();
        labelled.meta.set_label("app", "web");
        assert!(ListOptions::all()
            .with_field("metadata.labels.app", "web")
            .matches(&labelled));
        assert!(ListOptions::all().with_field("kind", "Pod").matches(&pod));
        assert!(!ListOptions::all().with_field("bogusroot.x", "1").matches(&pod));
    }

    #[test]
    fn label_selector_matches() {
        let mut pod = PodView::build("p", "img.sif", crate::cluster::Resources::ZERO, &[]);
        pod.meta.set_label("app", "web");
        assert!(ListOptions::all().with_label("app", "web").matches(&pod));
        assert!(!ListOptions::all().with_label("app", "db").matches(&pod));
    }

    #[test]
    fn options_wire_roundtrip() {
        let opts = ListOptions::all()
            .with_label("app", "web")
            .with_field("status.phase", "Running")
            .not_older_than(7)
            .with_limit(25)
            .continue_from("pod-00042")
            .delta_since(42);
        assert_eq!(ListOptions::from_value(&opts.to_value()), opts);
        assert_eq!(ListOptions::from_value(&Value::map()), ListOptions::all());
    }

    #[test]
    fn actor_client_pins_the_audit_actor() {
        use crate::cluster::Metrics;
        use crate::kube::ApiServer;
        let server = ApiServer::new(Metrics::new());
        let wrapped = ActorClient::wrap(server.client(), "kube-scheduler");
        wrapped
            .create(PodView::build("p", "img.sif", crate::cluster::Resources::ZERO, &[]))
            .unwrap();
        let records = server.audit_log().snapshot();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].actor, "kube-scheduler");
        // The pin is per-call: this thread's actor is untouched after.
        assert_eq!(crate::obs::current_actor(), None);
    }

    #[test]
    fn of_kind_validates_family() {
        use crate::cluster::Metrics;
        use crate::kube::api::WlmJobView;
        use crate::kube::ApiServer;
        let client: Arc<dyn ApiClient> = Arc::new(ApiServer::new(Metrics::new()));
        assert!(Api::<WlmJobView>::of_kind(client.clone(), "SlurmJob").is_ok());
        assert!(Api::<WlmJobView>::of_kind(client.clone(), "Pod").is_err());
        let pods = Api::<PodView>::new(client);
        assert_eq!(pods.kind(), KIND_POD);
        // Creating the wrong kind through a typed handle is rejected.
        let node = crate::kube::api::NodeView::build(
            "n",
            crate::cluster::Resources::cores(1, 1 << 30),
            &[],
        );
        assert!(pods.create(node).is_err());
    }
}
