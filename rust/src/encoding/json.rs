//! JSON encoder/decoder over [`Value`] — the red-box wire format and the
//! kube store's persistence format. Hand-rolled because the offline registry
//! has no serde_json; implements the full JSON grammar (RFC 8259) with
//! `\uXXXX` escapes incl. surrogate pairs.

use super::value::Value;
use crate::util::{Error, Result};

// ---------------------------------------------------------------- encoding

/// Serialize compactly (no whitespace) — wire/storage form.
pub fn to_string(v: &Value) -> String {
    let mut out = String::with_capacity(128);
    write_value(v, &mut out);
    out
}

/// Serialize with 2-space indentation — human-facing (`kubectl get -o json`).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::with_capacity(256);
    write_pretty(v, 0, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => write_f64(*f, out),
        Value::Str(s) => write_str(s, out),
        Value::Seq(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Value::Map(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_pretty(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Seq(xs) if !xs.is_empty() => {
            out.push_str("[\n");
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                push_indent(indent + 1, out);
                write_pretty(x, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push(']');
        }
        Value::Map(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                push_indent(indent + 1, out);
                write_str(k, out);
                out.push_str(": ");
                write_pretty(x, indent + 1, out);
            }
            out.push('\n');
            push_indent(indent, out);
            out.push('}');
        }
        other => write_value(other, out),
    }
}

fn push_indent(n: usize, out: &mut String) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_f64(f: f64, out: &mut String) {
    if f.is_finite() {
        let s = format!("{f}");
        out.push_str(&s);
        // Keep floats distinguishable from ints on re-parse.
        if !s.contains('.') && !s.contains('e') && !s.contains('E') {
            out.push_str(".0");
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\x08' => out.push_str("\\b"),
            '\x0c' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- decoding

/// Parse a JSON document. Trailing non-whitespace is an error.
pub fn parse(s: &str) -> Result<Value> {
    let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::parse(format!("trailing characters at byte {}", p.pos)));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(Error::parse(format!(
                "expected `{}` at byte {}",
                b as char,
                self.pos.saturating_sub(1)
            )))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(Error::parse(format!(
                "unexpected `{}` at byte {}",
                c as char, self.pos
            ))),
            None => Err(Error::parse("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(Error::parse(format!("invalid literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Map(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            entries.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Map(entries)),
                _ => return Err(Error::parse(format!("expected `,` or `}}` at byte {}", self.pos))),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Seq(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Seq(items)),
                _ => return Err(Error::parse(format!("expected `,` or `]` at byte {}", self.pos))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(Error::parse("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\x08'),
                    Some(b'f') => out.push('\x0c'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(Error::parse("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(Error::parse("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| Error::parse("invalid codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| Error::parse("invalid codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(Error::parse("invalid escape")),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-assemble multi-byte UTF-8 from the source.
                    let start = self.pos - 1;
                    let width = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(Error::parse("invalid utf-8 in string")),
                    };
                    if start + width > self.bytes.len() {
                        return Err(Error::parse("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + width])
                        .map_err(|_| Error::parse("invalid utf-8 in string"))?;
                    out.push_str(chunk);
                    self.pos = start + width;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| Error::parse("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| Error::parse("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Value::Float)
                .map_err(|_| Error::parse(format!("bad number `{text}`")))
        } else {
            text.parse::<i64>()
                .map(Value::Int)
                .or_else(|_| text.parse::<f64>().map(Value::Float))
                .map_err(|_| Error::parse(format!("bad number `{text}`")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Value) {
        let s = to_string(v);
        let back = parse(&s).unwrap();
        assert_eq!(&back, v, "roundtrip failed for {s}");
    }

    #[test]
    fn scalars() {
        roundtrip(&Value::Null);
        roundtrip(&Value::Bool(true));
        roundtrip(&Value::Int(-42));
        roundtrip(&Value::Float(3.5));
        roundtrip(&Value::str("hello"));
    }

    #[test]
    fn float_stays_float() {
        let s = to_string(&Value::Float(2.0));
        assert_eq!(s, "2.0");
        assert_eq!(parse(&s).unwrap(), Value::Float(2.0));
    }

    #[test]
    fn string_escapes() {
        roundtrip(&Value::str("line1\nline2\t\"quoted\" \\slash\\ \x08\x0c"));
        roundtrip(&Value::str("unicode: ü λ 🐍 — dash"));
        assert_eq!(parse(r#""Aü""#).unwrap(), Value::str("Aü"));
        // surrogate pair (🐍 U+1F40D)
        assert_eq!(parse(r#""🐍""#).unwrap(), Value::str("🐍"));
    }

    #[test]
    fn nested_structures() {
        let v = Value::map()
            .with("kind", "TorqueJob")
            .with("spec", Value::map().with("nodes", 2i64).with("ok", true))
            .with("items", Value::Seq(vec![Value::Int(1), Value::str("x"), Value::Null]));
        roundtrip(&v);
    }

    #[test]
    fn parse_errors() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn whitespace_tolerated() {
        let v = parse(" { \"a\" : [ 1 , 2 ] , \"b\" : null } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_seq().unwrap().len(), 2);
    }

    #[test]
    fn pretty_parses_back() {
        let v = Value::map()
            .with("a", Value::Seq(vec![Value::Int(1), Value::Int(2)]))
            .with("b", Value::map().with("c", "d"));
        let pretty = to_string_pretty(&v);
        assert!(pretty.contains('\n'));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn big_int_falls_to_float() {
        let v = parse("123456789012345678901234567890").unwrap();
        assert!(matches!(v, Value::Float(_)));
    }

    #[test]
    fn empty_containers() {
        roundtrip(&Value::Seq(vec![]));
        roundtrip(&Value::map());
        assert_eq!(to_string(&Value::map()), "{}");
    }

    #[test]
    fn nonfinite_float_becomes_null() {
        assert_eq!(to_string(&Value::Float(f64::NAN)), "null");
    }
}
