//! Quickstart: the paper's test case (§IV, Figs. 3–5), end to end.
//!
//! Boots the hybrid testbed (Fig. 1), applies the verbatim `cow_job.yaml`
//! manifest (Fig. 3), polls `kubectl get torquejob` (Fig. 4), and prints
//! the lolcow output staged by the results pod (Fig. 5).
//!
//! Run: `cargo run --release --example quickstart`

use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::KIND_TORQUEJOB;
use hpcorc::util::fmt_age;
use std::time::Duration;

fn main() {
    println!("=== hpcorc quickstart: Torque-Operator test case (paper §IV) ===\n");
    println!("Table I components: kube + pbs | singularity + singularity-cri | operator | rustc+jax-aot\n");

    let mut cfg = TestbedConfig::default();
    cfg.operator_deployment = true; // the operator's 4 service containers (§III-B)
    let tb = Testbed::start(cfg).expect("testbed boot");
    println!(
        "testbed up: torque queues {:?}, {} kube node objects (incl. virtual node), red-box at {}\n",
        tb.pbs.queues().names(),
        tb.api.list("Node", &[]).len(),
        tb.socket().display()
    );

    println!("$ kubectl apply -f cow_job.yaml     # Fig. 3 manifest");
    tb.kubectl_apply(hpcorc::kube::yaml::COW_JOB_YAML).expect("apply");

    // Fig. 4: show each phase transition as a kubectl table.
    let mut last = String::new();
    loop {
        let obj = tb.api.get(KIND_TORQUEJOB, "cow").expect("get torquejob");
        let phase = obj.status.opt_str("phase").unwrap_or("").to_string();
        if phase != last && !phase.is_empty() {
            println!("\n$ kubectl get torquejob");
            println!("{:<6} {:<5} {:<10}", "NAME", "AGE", "STATUS");
            let age = fmt_age(Duration::from_secs_f64(
                (tb.api.now_s() - obj.meta.creation_s).max(0.0),
            ));
            println!("{:<6} {:<5} {:<10}", "cow", age, phase);
            if let Some(job_id) = obj.status.opt_str("jobId") {
                println!("  (Torque job id: {job_id} — also visible via qstat on the login node)");
            }
            last = phase.clone();
        }
        if hpcorc::operator::phase::terminal(&phase) {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    println!("\n$ cat $HOME/low.out                 # Fig. 5: staged by the results pod");
    print!("{}", tb.fs.read_string("$HOME/low.out").expect("low.out"));
    println!("\nresults copy in mount dir: $HOME/low.out -> {}", if tb.fs.exists("$HOME/low.out") { "present" } else { "missing" });

    println!("\npods involved (dummy + results + operator services):");
    for pod in tb.api.list("Pod", &[]) {
        println!(
            "  {:<24} {:<10} node={}",
            pod.meta.name,
            pod.status.opt_str("phase").unwrap_or("Pending"),
            pod.spec.opt_str("nodeName").unwrap_or("<none>")
        );
    }
    tb.stop();
    println!("\nquickstart OK");
}
