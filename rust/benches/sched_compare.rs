//! E1 — the paper's promised evaluation (§V): scheduling efficiency of
//! container jobs under Kubernetes vs Torque disciplines, plus the hybrid
//! operator path, across workload families and load levels.
//!
//! Regenerates the full table the paper's future work describes; shapes to
//! check are summarised at the end (and recorded in EXPERIMENTS.md §E1).

use hpcorc::sched::{EasyBackfill, FifoPolicy, KubeGreedyPolicy, SchedPolicy};
use hpcorc::sim::{simulate, OperatorModel, SimParams};
use hpcorc::workload::TraceGen;

fn main() {
    println!("=== E1: K8s vs Torque scheduling efficiency (discrete-event sim, live policy code) ===\n");
    let params = SimParams { nodes: 16, cores_per_node: 8, ..SimParams::default() };
    let policies: Vec<Box<dyn SchedPolicy>> =
        vec![Box::new(FifoPolicy), Box::new(EasyBackfill), Box::new(KubeGreedyPolicy)];

    // Load sweep on the batch workload — where backfill pays.
    for load in [0.7, 0.9, 1.1] {
        let trace = TraceGen::new(11).poisson_batch(1500, 128, load, 180.0);
        println!("--- poisson batch, offered load {load} ({} jobs) ---", trace.len());
        for p in &policies {
            println!("  {}", simulate(&trace, &params, p.as_ref()).row());
        }
        let hybrid = SimParams {
            operator: OperatorModel { submit_delay_s: 0.5, poll_s: 0.25 },
            ..params.clone()
        };
        let mut r = simulate(&trace, &hybrid, &EasyBackfill);
        r.policy = "hybrid-op".into();
        println!("  {}", r.row());
        println!();
    }

    // Wide/narrow mix where FIFO head-blocks.
    let trace = TraceGen::new(12).backfill_showcase(30, 16);
    println!("--- backfill showcase ({} jobs) ---", trace.len());
    for p in &policies {
        println!("  {}", simulate(&trace, &params, p.as_ref()).row());
    }
    println!();

    // Service churn — K8s home turf.
    let trace = TraceGen::new(13).bursty(50, 30, 30.0);
    println!("--- bursty service churn ({} jobs) ---", trace.len());
    for p in &policies {
        println!("  {}", simulate(&trace, &params, p.as_ref()).row());
    }
    println!();

    // CYBELE pilot mix (the paper's named benchmark plan).
    let trace = TraceGen::new(14).cybele_pilots(40, 400, 4000.0);
    println!("--- cybele pilots ({} jobs) ---", trace.len());
    for p in &policies {
        println!("  {}", simulate(&trace, &params, p.as_ref()).row());
    }

    println!("\nshapes (expected / recorded in EXPERIMENTS.md §E1):");
    println!("  * batch @ load>=0.9: easy-backfill < fifo makespan, higher util");
    println!("  * kube-greedy: competitive mean wait, worst max-wait on wide jobs (starvation)");
    println!("  * hybrid-op ≈ easy-backfill + sub-second deltas (operator overhead, E2)");
}
