//! Informer-layer integration (PR 4 acceptance):
//!
//! 1. **Zero full-list RPCs in steady state** — a counting `ApiClient`
//!    wrapper proves that once seeded, scheduler + kueue admission + HPA
//!    + cluster-autoscaler + deployment-controller + metrics-publish
//!    cycles never issue a list again.
//! 2. **Resync recovery** — kill the watch streams, change the world
//!    (including a write burst larger than the store's retained history
//!    window, so the old bookmark is truly gone), and assert the
//!    reflectors relist, bump their resync epoch, the kueue ledger does a
//!    full rebuild, and the recovered controller converges to exactly the
//!    admitted set a fresh-start controller computes.

use hpcorc::autoscale::{
    publish_node_sample, CaConfig, ClusterAutoscaler, HpaController, HpaView, NodeProvisioner,
    KIND_PODMETRICS,
};
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::encoding::Value;
use hpcorc::kube::{
    ApiClient, ApiServer, Controller, DeploymentController, KubeObject, KubeScheduler,
    ListOptions, NodeView, ObjectList, PodView, SharedInformerFactory, WatchEvent, KIND_POD,
};
use hpcorc::kueue::{
    is_admitted, AdmissionCore, ClusterQueueView, LocalQueueView, QueueResources,
    QUEUE_NAME_LABEL,
};
use hpcorc::rt::Shutdown;
use hpcorc::util::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// ApiClient wrapper that counts list RPCs (and can sever watch streams
/// on demand), delegating everything to an in-process ApiServer.
struct InstrumentedApi {
    api: ApiServer,
    lists: AtomicU64,
    /// Live watch-forwarder kill switches (sever to simulate a remote
    /// server restart / stream loss).
    taps: Mutex<Vec<Shutdown>>,
}

impl InstrumentedApi {
    fn new(api: ApiServer) -> Arc<InstrumentedApi> {
        Arc::new(InstrumentedApi { api, lists: AtomicU64::new(0), taps: Mutex::new(Vec::new()) })
    }

    fn lists(&self) -> u64 {
        self.lists.load(Ordering::SeqCst)
    }

    fn reset_lists(&self) {
        self.lists.store(0, Ordering::SeqCst);
    }

    fn kill_streams(&self) {
        for sd in self.taps.lock().unwrap().drain(..) {
            sd.trigger();
        }
        // Give the severed forwarders a beat to drop their senders.
        std::thread::sleep(Duration::from_millis(10));
    }
}

impl ApiClient for InstrumentedApi {
    fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        self.api.create(obj)
    }
    fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.api.get(kind, name)
    }
    fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        ApiServer::update(&self.api, obj)
    }
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        self.api.update_status(kind, name, f)
    }
    fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
        self.api.patch_merge(kind, name, patch)
    }
    fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.api.delete(kind, name)
    }
    fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        self.api.apply(obj)
    }
    fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
        self.lists.fetch_add(1, Ordering::SeqCst);
        self.api.list_opts(kind, opts)
    }
    fn watch(&self, kind: Option<&str>, from: u64) -> Result<Receiver<WatchEvent>> {
        let upstream = ApiServer::watch(&self.api, kind, from);
        let (tx, rx) = channel();
        let sd = Shutdown::new();
        self.taps.lock().unwrap().push(sd.clone());
        hpcorc::rt::spawn_named("instrumented-watch", move || loop {
            if sd.is_triggered() {
                return; // drops tx: stream severed
            }
            match upstream.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => {
                    if tx.send(ev).is_err() {
                        return;
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(_) => return,
            }
        });
        Ok(rx)
    }
    fn server_time_s(&self) -> Result<f64> {
        Ok(self.api.now_s())
    }
}

/// Node-object provisioner (control-loop cost only, no kubelets).
struct ObjectProvisioner {
    api: ApiServer,
    capacity: Resources,
}

impl NodeProvisioner for ObjectProvisioner {
    fn provision(&self, name: &str, labels: &[(&str, &str)]) -> Result<()> {
        let mut node = NodeView::build(name, self.capacity, &[]);
        for (k, v) in labels {
            node.meta.set_label(k, v);
        }
        self.api.create(node)?;
        Ok(())
    }
    fn deprovision(&self, _name: &str) -> Result<()> {
        Ok(())
    }
}

fn queued_pod(name: &str, queue: &str, cpu: u64) -> KubeObject {
    let mut p = PodView::build(name, "img.sif", Resources::new(cpu, 1 << 20, 0), &[]);
    hpcorc::kueue::queue_workload(&mut p, queue);
    p
}

/// Acceptance: steady-state reconcile cycles of every control loop issue
/// **zero** full-list RPCs — every read is served by the shared caches.
#[test]
fn steady_state_cycles_issue_zero_list_rpcs() {
    let raw = ApiServer::new(Metrics::new());
    raw.register_mutating_hook(hpcorc::kueue::admission_mutating_hook());
    let counted = InstrumentedApi::new(raw.clone());
    let client: Arc<dyn ApiClient> = counted.clone();
    let informers = SharedInformerFactory::new(client, Metrics::new());

    // Every control loop, built on the same shared caches.
    let sched = KubeScheduler::new(&informers, Metrics::new());
    let deploy_ctrl = DeploymentController::new(&informers);
    let core = AdmissionCore::new(&informers, Metrics::new());
    let hpa = HpaController::new(&informers, Duration::from_millis(1), Metrics::new());
    let ca = ClusterAutoscaler::new(
        &informers,
        Arc::new(ObjectProvisioner { api: raw.clone(), capacity: Resources::cores(8, 64 << 30) }),
        CaConfig { max_nodes: 2, burst_wlm: None, ..CaConfig::default() },
        Metrics::new(),
    );
    let samples = informers.informer(KIND_PODMETRICS);

    // ---- world: nodes, a sampled deployment + HPA, a kueue tenant ----
    counted.create(NodeView::build("w1", Resources::cores(8, 64 << 30), &[])).unwrap();
    counted
        .create(DeploymentController::build(
            "web",
            2,
            "svc.sif",
            Resources::new(500, 64 << 20, 0),
        ))
        .unwrap();
    counted.create(HpaView::build("h", "web", 1, 4, 50, Duration::ZERO)).unwrap();
    counted.create(ClusterQueueView::build("cq", QueueResources::nodes(2))).unwrap();
    counted.create(LocalQueueView::build("team", "cq")).unwrap();
    counted.create(queued_pod("q0", "team", 100)).unwrap();
    counted.create(queued_pod("q1", "team", 100)).unwrap();

    let step = || {
        let _ = deploy_ctrl.reconcile(counted.as_ref() as &dyn ApiClient, "web");
        let _ = core.cycle(counted.as_ref() as &dyn ApiClient);
        sched.run_cycle();
        // Mark deployment pods Running so HPA has a stable signal.
        for pod in raw.list(KIND_POD, &[("deployment".to_string(), "web".to_string())]) {
            if pod.spec.opt_str("nodeName").is_some()
                && pod.status.opt_str("phase") != Some("Running")
            {
                raw.update_status(KIND_POD, &pod.meta.name, |o| {
                    o.status.insert("phase", "Running");
                })
                .unwrap();
            }
        }
        publish_node_sample(
            counted.as_ref() as &dyn ApiClient,
            &samples,
            "w1",
            Resources::cores(8, 64 << 30),
            &informers.informer(KIND_POD).list_by_field("spec.nodeName", "w1"),
            &Metrics::new(),
        );
        let _ = hpa.reconcile(counted.as_ref() as &dyn ApiClient, "h");
        let _ = ca.run_cycle();
    };

    // Converge: replicas placed + running, both queued pods admitted.
    for _ in 0..10 {
        step();
    }
    assert!(is_admitted(&raw.get(KIND_POD, "q0").unwrap()), "tenant pods admitted");
    assert!(is_admitted(&raw.get(KIND_POD, "q1").unwrap()));
    assert!(counted.lists() > 0, "seeding had to list at least once");

    // ---- steady state: every loop cycles, nothing may list ----------
    counted.reset_lists();
    let rebuilds_before = core.ledger_rebuilds();
    for _ in 0..25 {
        step();
    }
    assert_eq!(
        counted.lists(),
        0,
        "steady-state scheduler + kueue + autoscale cycles must issue zero list RPCs"
    );
    assert_eq!(
        core.ledger_rebuilds(),
        rebuilds_before,
        "steady-state events must never force a ledger rebuild"
    );
}

/// Acceptance: the 410-Gone flow. Sever the watch streams, mutate the
/// world with a burst larger than the retained history window, and the
/// reflectors must relist + bump their resync epoch, the kueue ledger
/// must fully rebuild, and the recovered controller must converge to the
/// same admitted set as a controller started fresh from the API.
#[test]
fn watch_loss_past_history_window_relists_and_rebuilds_ledger() {
    // Tiny retained window: the blind-spot burst below evicts every
    // bookmark the severed streams ever held.
    let raw = ApiServer::with_history_cap(Metrics::new(), 64);
    let counted = InstrumentedApi::new(raw.clone());
    let client: Arc<dyn ApiClient> = counted.clone();
    let informers = SharedInformerFactory::new(client, Metrics::new());
    let core = AdmissionCore::new(&informers, Metrics::new());

    counted.create(ClusterQueueView::build("cq", QueueResources::nodes(2))).unwrap();
    counted.create(LocalQueueView::build("team", "cq")).unwrap();
    counted.create(queued_pod("p0", "team", 100)).unwrap();
    counted.create(queued_pod("p1", "team", 100)).unwrap();
    counted.create(queued_pod("p2", "team", 100)).unwrap();

    let r = core.cycle(counted.as_ref() as &dyn ApiClient).unwrap();
    assert_eq!(r.admitted, 2, "2-node quota admits p0+p1");
    assert!(!is_admitted(&raw.get(KIND_POD, "p2").unwrap()));
    assert_eq!(core.ledger_rebuilds(), 1, "cold start built the ledger once");
    let pod_epoch = informers.informer(KIND_POD).epoch();

    // ---- the blind spot --------------------------------------------
    counted.kill_streams();
    // p0 completes (frees one node) while the informers see nothing...
    raw.update_status(KIND_POD, "p0", |o| {
        o.status.insert("phase", "Succeeded");
    })
    .unwrap();
    // ...and a write burst far beyond the 64-event window guarantees the
    // severed bookmarks fell out of retained history (a relist is the
    // only possible recovery, not a replay).
    raw.create(KubeObject::new("Widget", "spam", Value::map())).unwrap();
    for i in 0..200u64 {
        raw.update_status("Widget", "spam", |o| {
            o.status.insert("n", i);
        })
        .unwrap();
    }
    let (_, _, reset) = raw.events_since(None, 1);
    assert!(reset, "burst must overflow the retained history window");

    // ---- recovery ---------------------------------------------------
    let r = core.cycle(counted.as_ref() as &dyn ApiClient).unwrap();
    assert!(
        informers.informer(KIND_POD).epoch() > pod_epoch,
        "stream loss must bump the resync epoch"
    );
    assert_eq!(core.ledger_rebuilds(), 2, "epoch bump must force a full ledger rebuild");
    assert_eq!(r.admitted, 1, "freed quota admits p2 after recovery");
    assert!(is_admitted(&raw.get(KIND_POD, "p1").unwrap()));
    assert!(is_admitted(&raw.get(KIND_POD, "p2").unwrap()));

    // ---- equivalence with a fresh start -----------------------------
    // A brand-new controller over a brand-new factory sees the same
    // world: it must agree completely (no admissions, no preemptions, no
    // writes) — recovery converged to the fresh-start fixed point.
    let fresh_informers =
        SharedInformerFactory::new(counted.clone() as Arc<dyn ApiClient>, Metrics::new());
    let fresh_core = AdmissionCore::new(&fresh_informers, Metrics::new());
    let version_before = raw.current_version();
    let r = fresh_core.cycle(counted.as_ref() as &dyn ApiClient).unwrap();
    assert_eq!((r.admitted, r.preempted), (0, 0), "fresh start finds nothing to change");
    assert_eq!(
        raw.current_version(),
        version_before,
        "fresh start writes nothing: recovered state is already the fixed point"
    );
    let cq = ClusterQueueView::from_object(
        &raw.get(hpcorc::kueue::KIND_CLUSTERQUEUE, "cq").unwrap(),
    )
    .unwrap();
    assert_eq!((cq.pending, cq.admitted), (0, 2), "counts reflect the converged set");
}

/// ISSUE 5 acceptance: over a *streaming* remote transport, an idle
/// informer performs **zero** RPC round-trips — events are pushed as
/// frames, so steady-state `sync()` only drains a local channel. The
/// poll fallback on the same server keeps issuing ~10 RPCs/s while idle,
/// which is exactly the traffic the streaming watch removes. Round-trips
/// are counted on the server (every `Request` frame increments
/// `redbox.requests`), so nothing client-side can hide traffic.
#[test]
fn idle_streaming_informer_issues_zero_rpc_round_trips() {
    use hpcorc::kube::{RemoteApi, WatchConfig, WatchMode};
    use hpcorc::redbox::RedboxServer;

    let sd = Shutdown::new();
    let path = std::env::temp_dir()
        .join(format!("hpcorc-informer-stream-{}.sock", std::process::id()));
    let server_metrics = Metrics::new();
    let mut srv = RedboxServer::start(&path, sd.clone(), server_metrics.clone()).unwrap();
    let api = ApiServer::new(Metrics::new());
    srv.register("kube.Api", api.rpc_service());

    // ---- streaming remote informer ----------------------------------
    let remote = Arc::new(RemoteApi::connect(&path).unwrap());
    let informers =
        SharedInformerFactory::new(remote.clone() as Arc<dyn ApiClient>, Metrics::new());
    let pods = informers.informer(KIND_POD);
    api.create(PodView::build("p0", "img.sif", Resources::new(100, 1 << 20, 0), &[]))
        .unwrap();
    pods.sync().unwrap(); // seed: one paged list + one watch open
    assert_eq!(pods.len(), 1);
    assert_eq!(remote.last_watch_mode(), Some(WatchMode::Streaming));

    // Steady state, fully idle: not one request crosses the socket.
    let base = server_metrics.counter_value("redbox.requests");
    for _ in 0..40 {
        pods.sync().unwrap();
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(
        server_metrics.counter_value("redbox.requests"),
        base,
        "idle streaming informer must issue zero RPC round-trips"
    );

    // Event delivery is push too: the cache catches up with still zero
    // round-trips issued by this client.
    api.create(PodView::build("p1", "img.sif", Resources::new(100, 1 << 20, 0), &[]))
        .unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while pods.get("p1").is_none() {
        assert!(std::time::Instant::now() < deadline, "pushed event never arrived");
        pods.sync().unwrap();
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(
        server_metrics.counter_value("redbox.requests"),
        base,
        "event delivery must be server-push, not poll"
    );

    // ---- the poll fallback, for contrast (~10 RPCs/s idle) -----------
    let poll_remote = Arc::new(
        RemoteApi::connect(&path)
            .unwrap()
            .with_watch_config(WatchConfig { force_poll: true, ..WatchConfig::default() }),
    );
    let poll_informers =
        SharedInformerFactory::new(poll_remote.clone() as Arc<dyn ApiClient>, Metrics::new());
    let poll_pods = poll_informers.informer(KIND_POD);
    poll_pods.sync().unwrap();
    assert_eq!(poll_remote.last_watch_mode(), Some(WatchMode::Poll));
    let poll_base = server_metrics.counter_value("redbox.requests");
    std::thread::sleep(Duration::from_millis(500));
    assert!(
        server_metrics.counter_value("redbox.requests") > poll_base + 2,
        "the poll fallback keeps polling while idle (this is the traffic streaming removes)"
    );
    srv.stop();
}

/// The scheduler stays event-correct through the mutating hook: a pod
/// born with a bare queue-name label can never be bound before its first
/// admission cycle, even if the scheduler runs first.
#[test]
fn mutating_hook_closes_the_scheduler_race() {
    let raw = ApiServer::new(Metrics::new());
    raw.register_mutating_hook(hpcorc::kueue::admission_mutating_hook());
    let informers = SharedInformerFactory::new(raw.client(), Metrics::new());
    let sched = KubeScheduler::new(&informers, Metrics::new());
    raw.create(NodeView::build("w1", Resources::cores(8, 64 << 30), &[])).unwrap();
    // Bare label — no gate in the manifest, exactly the old race shape.
    let mut bare = PodView::build("bare", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
    bare.meta.set_label(QUEUE_NAME_LABEL, "team");
    raw.create(bare).unwrap();
    // Scheduler runs before any admission cycle ever happened.
    assert_eq!(sched.run_cycle(), 0, "hook-gated pod must not bind");
    assert!(raw.get(KIND_POD, "bare").unwrap().spec.opt_str("nodeName").is_none());
    // An unlabelled pod binds normally through the same path.
    raw.create(PodView::build("plain", "img.sif", Resources::new(100, 1 << 20, 0), &[]))
        .unwrap();
    assert_eq!(sched.run_cycle(), 1);
}

/// PR 7: an informer subscriber receives watch-delivered objects carrying
/// the `hpcorc.io/trace` annotation the originating write stamped — the
/// causal chain survives store → WAL → watch → cache → subscriber.
#[test]
fn informer_events_carry_the_originating_writes_trace() {
    use hpcorc::obs;

    let api = ApiServer::new(Metrics::new());
    let informer_metrics = Metrics::new();
    let informers = SharedInformerFactory::new(api.client(), informer_metrics.clone());
    let pods = informers.informer(KIND_POD);
    pods.sync().unwrap();
    let rx = pods.subscribe();

    let guard = obs::span("informer-test", "traced create");
    let root = guard.context().expect("tracing enabled by default");
    api.create(PodView::build("traced", "img.sif", Resources::new(100, 1 << 20, 0), &[]))
        .unwrap();
    drop(guard);
    pods.sync().unwrap();

    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let annotated = loop {
        assert!(std::time::Instant::now() < deadline, "no informer event for traced pod");
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(ev) => {
                if let Some(o) = ev.object() {
                    if o.meta.name == "traced" {
                        break o
                            .meta
                            .annotation(obs::TRACE_ANNOTATION)
                            .expect("cached object keeps the trace annotation")
                            .to_string();
                    }
                }
            }
            Err(_) => {
                // Poll transports may lag; pump the reflector again.
                let _ = pods.sync();
            }
        }
    };
    let ctx = obs::TraceContext::parse_wire(&annotated).expect("well-formed wire context");
    assert_eq!(
        ctx.trace_id, root.trace_id,
        "informer-delivered object joined a different trace than the originating write"
    );
    // The delivery itself was timed (the informer's fan-out histogram).
    let delivered =
        informer_metrics.hist("kube.informer.deliver_ns").lock().unwrap().count();
    assert!(delivered >= 1, "informer delivery latency must be observed");
}
