"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (including tile-boundary and non-preferred-tile
cases) and asserts allclose — the core correctness signal of the compile
path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention, attention_fwd, vmem_bytes as attn_vmem
from compile.kernels.matmul_gelu import (
    matmul_gelu,
    matmul_gelu_fwd,
    mxu_utilization_estimate,
    vmem_bytes as mm_vmem,
)

TOL = dict(rtol=2e-4, atol=2e-4)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ------------------------------------------------------------ matmul_gelu

dims = st.sampled_from([8, 16, 24, 32, 48, 64, 96, 128])


@settings(max_examples=20, deadline=None)
@given(m=dims, k=dims, n=dims, act=st.sampled_from(["gelu", "none"]))
def test_matmul_gelu_matches_ref(m, k, n, act):
    x = rand(1, (m, k))
    w = rand(2, (k, n))
    b = rand(3, (1, n))
    out = matmul_gelu_fwd(x, w, b, activation=act)
    expect = ref.matmul_gelu_ref(x, w, b, act)
    np.testing.assert_allclose(out, expect, **TOL)


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([16, 64]),
    bm=st.sampled_from([8, 16]),
    bk=st.sampled_from([8, 16]),
    bn=st.sampled_from([8, 16]),
)
def test_matmul_gelu_tile_choices_equivalent(m, bm, bk, bn):
    """Any legal tiling yields identical numerics (K-accumulation order)."""
    x = rand(4, (m, 32))
    w = rand(5, (32, 16))
    b = rand(6, (1, 16))
    base = ref.matmul_gelu_ref(x, w, b)
    out = matmul_gelu_fwd(x, w, b, bm=bm, bk=bk, bn=bn)
    np.testing.assert_allclose(out, base, **TOL)


def test_matmul_gelu_grad_matches_ref():
    x = rand(7, (32, 24))
    w = rand(8, (24, 16))
    b = rand(9, (1, 16))

    def f_kernel(x, w, b):
        return (matmul_gelu(x, w, b, "gelu") ** 2).sum()

    def f_ref(x, w, b):
        return (ref.matmul_gelu_ref(x, w, b, "gelu") ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-3, atol=1e-3)


def test_matmul_gelu_jit_and_vmem_estimates():
    x, w, b = rand(1, (64, 64)), rand(2, (64, 64)), rand(3, (1, 64))
    out = jax.jit(lambda x, w, b: matmul_gelu(x, w, b, "gelu"))(x, w, b)
    np.testing.assert_allclose(out, ref.matmul_gelu_ref(x, w, b), **TOL)
    assert mm_vmem(128, 128, 128) == 4 * (128 * 128 * 3 + 128 + 128 * 128)
    assert 0.0 < mxu_utilization_estimate(64, 64, 64) <= 1.0
    assert mxu_utilization_estimate(128, 128, 128) == 1.0


def test_matmul_gelu_bad_shapes():
    with pytest.raises(AssertionError):
        matmul_gelu_fwd(rand(1, (8, 8)), rand(2, (9, 8)), rand(3, (1, 8)))
    with pytest.raises(AssertionError):
        matmul_gelu_fwd(rand(1, (8, 8)), rand(2, (8, 8)), rand(3, (8,)))


# -------------------------------------------------------------- attention

@settings(max_examples=14, deadline=None)
@given(
    bh=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([8, 16, 32, 64]),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
)
def test_attention_matches_ref(bh, seq, d, causal):
    q = rand(11, (bh, seq, d))
    k = rand(12, (bh, seq, d))
    v = rand(13, (bh, seq, d))
    out = attention_fwd(q, k, v, causal=causal)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expect, **TOL)


@settings(max_examples=6, deadline=None)
@given(bq=st.sampled_from([4, 8, 16]), bk=st.sampled_from([4, 8, 16]))
def test_attention_block_sizes_equivalent(bq, bk):
    q = rand(14, (2, 16, 8))
    k = rand(15, (2, 16, 8))
    v = rand(16, (2, 16, 8))
    out = attention_fwd(q, k, v, bq=bq, bk=bk)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), **TOL)


def test_attention_grad_matches_ref():
    q = rand(17, (2, 16, 8))
    k = rand(18, (2, 16, 8))
    v = rand(19, (2, 16, 8))

    def f_kernel(q, k, v):
        return (attention(q, k, v, False) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.attention_ref(q, k, v) ** 2).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-3, atol=1e-3)


def test_attention_online_softmax_stability():
    """Large score magnitudes must not overflow (the online max rescaling)."""
    q = rand(20, (1, 16, 8), scale=30.0)
    k = rand(21, (1, 16, 8), scale=30.0)
    v = rand(22, (1, 16, 8))
    out = attention_fwd(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), rtol=2e-3, atol=2e-3)


def test_attention_vmem_estimate_positive():
    assert attn_vmem(8, 128, 64) > 0
