//! Fault-injection harness overhead (PR 10): what the chaos layer costs
//! when it is in the path, and what a full seeded scenario costs end to
//! end.
//!
//! - `chaos/plan_draw`: one [`FaultPlan`] decision — the per-call price
//!   every decorated verb pays (mutex + one PCG draw).
//! - `chaos/api_get_raw`: baseline in-process `get` through the plain
//!   client, for comparison.
//! - `chaos/faulty_api_get_pass`: the same `get` through a [`FaultyApi`]
//!   whose mix never injects — the decorator's pass-path overhead (op
//!   label format + schedule draw). Asserted to stay within a small
//!   multiple of the raw call, so chaos can wrap hot loops without
//!   distorting what they measure.
//! - `chaos/transcript_500`: render the AGE-stripped fixed-point
//!   transcript over 500 pods + 8 nodes — the convergence probe every
//!   scenario polls in its wait loops.
//! - `chaos/scenario_redbox_drop`: one full scenario run (golden testbed
//!   + faulted testbed, boot to converged transcript) — the end-to-end
//!   number the CI chaos job's wall-clock rides on.
//!
//! Prints `{"bench":...}` JSON rows for the CI perf trajectory.

use hpcorc::bench::{header, Bench};
use hpcorc::chaos::{self, FaultLog, FaultPlan, FaultyApi};
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::kube::{ApiClient, ApiServer, NodeView, PodView};

fn main() {
    println!("== chaos harness overhead (PR 10) ==");
    println!("{}", header());
    let mut rows = Vec::new();

    // One schedule decision: the fixed per-verb cost of being decorated.
    let plan = FaultPlan::new(7, 1);
    rows.push(Bench::new("chaos/plan_draw").warmup(1000).iters(50_000).run(|| {
        std::hint::black_box(plan.next());
    }));

    // Raw vs decorated get against the same in-process server. The
    // pass-only mix (0/0/0) means the decorator never injects — what is
    // left is exactly its bookkeeping.
    let server = ApiServer::new(Metrics::new());
    let pod = PodView::build("bench-pod", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
    server.create(pod).unwrap();
    let raw = server.client();
    rows.push(Bench::new("chaos/api_get_raw").warmup(500).iters(20_000).run(|| {
        std::hint::black_box(raw.get("Pod", "bench-pod").unwrap());
    }));
    let faulty = FaultyApi::new(server.client(), FaultPlan::new(7, 1).with_mix(0.0, 0.0, 0.0), FaultLog::new());
    rows.push(Bench::new("chaos/faulty_api_get_pass").warmup(500).iters(20_000).run(|| {
        std::hint::black_box(faulty.get("Pod", "bench-pod").unwrap());
    }));

    // The convergence probe: transcript over a populated store. Every
    // scenario wait-loop renders this once per poll tick.
    let big = ApiServer::new(Metrics::new());
    for i in 0..8u32 {
        big.create(NodeView::build(&format!("bn{i:02}"), Resources::cores(64, 1 << 34), &[]))
            .unwrap();
    }
    for i in 0..500u32 {
        big.create(PodView::build(
            &format!("bp{i:03}"),
            "img.sif",
            Resources::new(50, 1 << 20, 0),
            &[],
        ))
        .unwrap();
    }
    let big_client = big.client();
    rows.push(Bench::new("chaos/transcript_500").warmup(2).iters(50).run(|| {
        std::hint::black_box(chaos::scenarios::transcript(big_client.as_ref()));
    }));

    // One full scenario: two live testbeds (clean golden + faulted),
    // booted, driven to their fixed points, diffed. Must converge — a
    // diverging bench run means the harness itself regressed.
    rows.push(Bench::new("chaos/scenario_redbox_drop").warmup(0).iters(2).run(|| {
        let report = chaos::run_scenario("redbox-drop", 7).expect("scenario run");
        assert!(report.converged(), "bench scenario diverged:\n{}", report.render());
    }));

    println!();
    for s in &rows {
        println!("{}", s.json());
    }

    // Guardrail: the pass path must stay cheap enough to wrap hot loops.
    // Generous margin (5x + 2µs slack) to stay CI-stable — the decorator
    // adds one op-label format and one locked PCG draw per call.
    let raw_ns = rows[1].mean_ns;
    let pass_ns = rows[2].mean_ns;
    assert!(
        pass_ns <= raw_ns * 5.0 + 2_000.0,
        "FaultyApi pass path ({pass_ns:.0}ns) dwarfs the raw call ({raw_ns:.0}ns)"
    );
}
