//! Deterministic PRNG + distributions.
//!
//! The offline registry has no `rand` crate, so workload generation and the
//! discrete-event simulator use this PCG64-based generator. Determinism
//! matters: every bench/sim run is reproducible from a seed, which is how
//! EXPERIMENTS.md numbers are regenerated.

/// PCG-XSL-RR 128/64 generator (O'Neill 2014). 128-bit state, 64-bit output.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    /// Seeded generator; `stream` selects an independent sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for unbiased sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability p.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with given rate (mean = 1/rate). Inter-arrival times of a
    /// Poisson process — the standard model for job submissions.
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0,1] so ln() is finite
        -u.ln() / rate
    }

    /// Poisson-distributed count with given mean (Knuth for small, normal
    /// approximation for large means).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let n = mean + mean.sqrt() * self.normal();
            if n < 0.0 {
                0
            } else {
                n.round() as u64
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with given log-space mu/sigma. HPC job runtimes and sizes
    /// are classically log-normal (Feitelson workload models).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pick an index according to the given non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random lowercase alphanumeric suffix (pod-name style).
    pub fn suffix(&mut self, len: usize) -> String {
        const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789";
        (0..len)
            .map(|_| ALPHABET[self.below(ALPHABET.len() as u64) as usize] as char)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Rng::with_stream(42, 1);
        let mut b = Rng::with_stream(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(1);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exp(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(4);
        for &m in &[0.5, 5.0, 80.0] {
            let n = 20_000;
            let sum: u64 = (0..n).map(|_| r.poisson(m)).sum();
            let mean = sum as f64 / n as f64;
            assert!((mean - m).abs() < m.max(1.0) * 0.05, "mean {mean} vs {m}");
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(5);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        assert!((counts[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn suffix_shape() {
        let mut r = Rng::new(8);
        let s = r.suffix(5);
        assert_eq!(s.len(), 5);
        assert!(s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit()));
    }
}
