//! Fixed-size worker thread pool for short tasks (container launches,
//! result staging, RPC handler offload).

use super::Shutdown;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// A work-stealing-free, shared-queue thread pool.
pub struct Pool {
    tx: Option<Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn `n` workers named `{name}-{i}`.
    pub fn new(name: &str, n: usize) -> Self {
        assert!(n > 0, "pool needs at least one worker");
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = rx.clone();
                super::spawn_named(&format!("{name}-{i}"), move || loop {
                    let task = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match task {
                        Ok(t) => t(),
                        Err(_) => break, // all senders dropped
                    }
                })
            })
            .collect();
        Pool { tx: Some(tx), workers }
    }

    /// Submit a task. Panics if the pool is shut down.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Drain and join. Pending tasks complete first.
    pub fn shutdown(&mut self) {
        self.tx.take(); // close the channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Run tasks from `items` with up to `parallelism` threads and collect the
/// results in input order (scoped fan-out; used by benches and the sim).
pub fn scoped_map<T, R, F>(items: Vec<T>, parallelism: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let slots: Vec<Mutex<&mut Option<R>>> =
        results.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..parallelism.max(1).min(n.max(1)) {
            s.spawn(|| loop {
                let next = work.lock().unwrap().next();
                match next {
                    Some((i, item)) => {
                        let r = f(item);
                        **slots[i].lock().unwrap() = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    drop(slots);
    results.into_iter().map(|r| r.expect("scoped_map slot unfilled")).collect()
}

/// Convenience: a shutdown-aware periodic loop in its own thread.
pub fn spawn_ticker<F>(
    name: &str,
    period: std::time::Duration,
    shutdown: Shutdown,
    mut tick: F,
) -> JoinHandle<()>
where
    F: FnMut() + Send + 'static,
{
    super::spawn_named(name, move || loop {
        if shutdown.wait_timeout(period) {
            return;
        }
        tick();
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn pool_runs_all_tasks() {
        let mut pool = Pool::new("test", 4);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = count.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scoped_map_preserves_order() {
        let out = scoped_map((0..64).collect(), 8, |i: i32| i * 2);
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_map_empty() {
        let out: Vec<i32> = scoped_map(Vec::<i32>::new(), 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn ticker_ticks_and_stops() {
        let shutdown = Shutdown::new();
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        let h = spawn_ticker("tick", Duration::from_millis(5), shutdown.clone(), move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(60));
        shutdown.trigger();
        h.join().unwrap();
        let n = count.load(Ordering::SeqCst);
        assert!(n >= 3, "expected several ticks, got {n}");
    }
}
