//! Reflector / shared-informer layer: the machinery that lets every
//! control loop read a local cache instead of re-listing the world.
//!
//! The Kubernetes control plane scales because controllers do not issue
//! `list()` per reconcile: a **reflector** seeds a local cache with one
//! (paged) list, then tails `watch()` events into it forever; consumers
//! read the cache and subscribe to its event stream. This module is that
//! pattern over the PR 1 [`ApiClient`] trait, so the same reflector runs
//! in-process next to the store or across the red-box socket — and since
//! the remote watch is server-push (ISSUE 5), a steady-state informer is
//! RPC-silent on either transport: events arrive as pushed frames, and
//! `sync()` only drains a local channel:
//!
//! - [`Informer`] — a shared per-kind read handle: `get`/`list`, indexed
//!   reads ([`Informer::list_labelled`], [`Informer::list_by_field`],
//!   [`Informer::list_owned_by`]), a zero-copy [`Informer::read`] scan,
//!   and event subscriptions ([`Informer::subscribe`]) that replay the
//!   current cache and then stream deltas.
//! - [`SharedInformerFactory`] — one reflector per kind, shared by every
//!   consumer in the process (scheduler, kubelets, controllers, kueue,
//!   autoscalers all read the *same* pod cache), plus a pump thread
//!   ([`SharedInformerFactory::start`]) that drains watch streams.
//!
//! # The 410-Gone contract, and delta relists (PR 6)
//!
//! A reflector whose watch stream ends first attempts a **delta relist**
//! ([`ListOptions::delta_since`] from its bookmark): when the server's
//! per-kind history window still covers the bookmark, the answer is just
//! the changed objects + deleted names, which the reflector applies as
//! ordinary events — the cache epoch does not move and **no `Resync` is
//! emitted**, so event-derived state (the kueue ledger) stays
//! incremental. Only when the bookmark is genuinely out of window (the
//! real 410-Gone) does the reflector fall back to a full relist, **bump
//! its resync epoch, and emit [`InformerEvent::Resync`]**. Derived state
//! keyed on individual events must rebuild from the cache when it
//! observes an epoch bump, because events may have been lost in the gap.
//! Steady state performs zero list RPCs; the relist is the
//! explicitly-signalled exception.
//!
//! # Determinism
//!
//! [`Informer::sync`] drains pending events synchronously, so tests step
//! `create → sync → read` without daemon threads; the factory's pump
//! thread is only needed for event-driven daemons.

use super::api::KubeObject;
use super::client::{ApiClient, ListOptions};
use super::store::WatchEvent;
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::rt::Shutdown;
use crate::util::Result;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Page size for the seeding list (bounds per-RPC payloads; the seed of a
/// 100k-object kind is 200 bounded pages, not one giant response).
pub const DEFAULT_LIST_PAGE: usize = 500;

/// What subscribers receive. `Applied` covers both Added and Modified —
/// consumers are level-triggered and treat them identically.
#[derive(Debug, Clone)]
pub enum InformerEvent {
    /// Object created or modified (the current object is attached).
    Applied(KubeObject),
    /// Object deleted (the last-seen object is attached).
    Deleted(KubeObject),
    /// The reflector relisted after losing its watch stream: events may
    /// have been lost. Rebuild event-derived state from the cache.
    Resync { epoch: u64 },
}

impl InformerEvent {
    /// The object the event is about (`None` for `Resync`).
    pub fn object(&self) -> Option<&KubeObject> {
        match self {
            InformerEvent::Applied(o) | InformerEvent::Deleted(o) => Some(o),
            InformerEvent::Resync { .. } => None,
        }
    }
}

/// The secondary indexes over one kind's cache. A separate struct so
/// index maintenance can borrow the indexes mutably while the object map
/// is only read — no object clones on the per-event hot path or during a
/// relist.
#[derive(Default)]
struct Indexes {
    /// (label key, value) → names.
    by_label: HashMap<(String, String), BTreeSet<String>>,
    /// label key (any value) → names; what lets kueue scan only labelled
    /// workloads out of a large pod population.
    by_label_key: HashMap<String, BTreeSet<String>>,
    /// (registered field path, rendered value) → names.
    by_field: HashMap<(String, String), BTreeSet<String>>,
    /// (owner kind, owner name) → names.
    by_owner: HashMap<(String, String), BTreeSet<String>>,
    /// Field paths maintained in `by_field`.
    field_paths: Vec<String>,
}

/// One event subscription. `label_key` restricts delivery to objects
/// carrying that label key (Resync always passes) — what lets kueue
/// ignore the unlabelled pod churn of a cluster that never opted into
/// queueing without paying a clone per event.
struct Subscriber {
    tx: Sender<InformerEvent>,
    label_key: Option<String>,
}

struct CacheState {
    objects: BTreeMap<String, KubeObject>,
    indexes: Indexes,
    /// Store version the cache has caught up to (watch bookmark).
    version: u64,
    /// Bumped on every post-seed relist (the 410 recovery).
    epoch: u64,
    seeded: bool,
    rx: Option<Receiver<WatchEvent>>,
    subs: Vec<Subscriber>,
    /// Payload-free wake-up channels ([`Informer::subscribe_notify`]) —
    /// pinged on every event without cloning any object.
    notifiers: Vec<Sender<()>>,
}

/// Rendered value of a field path for indexing — same comparison contract
/// as [`ListOptions`] field selectors (strings verbatim, other scalars by
/// their compact-JSON rendering). Only `spec.*` / `status.*` roots are
/// indexable; everything else falls back to the scan path.
fn field_value(obj: &KubeObject, path: &str) -> Option<String> {
    let (root, rest) = path.split_once('.').unwrap_or((path, ""));
    let tree = match root {
        "spec" => &obj.spec,
        "status" => &obj.status,
        _ => return None,
    };
    let v = if rest.is_empty() {
        Some(tree)
    } else {
        let parts: Vec<&str> = rest.split('.').collect();
        tree.path(&parts)
    }?;
    Some(match v {
        Value::Str(s) => s.clone(),
        other => other.to_string(),
    })
}

impl Indexes {
    fn insert(&mut self, obj: &KubeObject) {
        let name = obj.meta.name.clone();
        for (k, v) in &obj.meta.labels {
            self.by_label.entry((k.clone(), v.clone())).or_default().insert(name.clone());
            self.by_label_key.entry(k.clone()).or_default().insert(name.clone());
        }
        for path in &self.field_paths {
            if let Some(val) = field_value(obj, path) {
                self.by_field.entry((path.clone(), val)).or_default().insert(name.clone());
            }
        }
        if let Some((k, n)) = &obj.meta.owner {
            self.by_owner.entry((k.clone(), n.clone())).or_default().insert(name);
        }
    }

    fn remove(&mut self, obj: &KubeObject) {
        let name = obj.meta.name.as_str();
        for (k, v) in &obj.meta.labels {
            prune(&mut self.by_label, &(k.clone(), v.clone()), name);
            prune(&mut self.by_label_key, k, name);
        }
        for path in &self.field_paths {
            if let Some(val) = field_value(obj, path) {
                prune(&mut self.by_field, &(path.clone(), val), name);
            }
        }
        if let Some(owner) = &obj.meta.owner {
            prune(&mut self.by_owner, owner, name);
        }
    }

    /// Rebuild from scratch over the (separately borrowed) object map —
    /// relists reindex without cloning a single object.
    fn rebuild(&mut self, objects: &BTreeMap<String, KubeObject>) {
        self.by_label.clear();
        self.by_label_key.clear();
        self.by_field.clear();
        self.by_owner.clear();
        for o in objects.values() {
            self.insert(o);
        }
    }
}

fn prune<K: std::hash::Hash + Eq + Clone>(
    index: &mut HashMap<K, BTreeSet<String>>,
    key: &K,
    name: &str,
) {
    if let Some(set) = index.get_mut(key) {
        set.remove(name);
        if set.is_empty() {
            index.remove(key);
        }
    }
}

/// `prev_labels` is the label set the cached object carried *before* this
/// event: a label-key-filtered subscriber is also served when the key was
/// just removed (the event object no longer carries it), so derived state
/// like the kueue ledger can uncharge incrementally instead of waiting
/// for a resync rebuild.
fn forward(st: &mut CacheState, ev: &InformerEvent, prev_labels: Option<&[(String, String)]>) {
    st.subs.retain(|s| {
        let wanted = match (&s.label_key, ev.object()) {
            (Some(key), Some(o)) => {
                o.meta.labels.iter().any(|(k, _)| k == key)
                    || prev_labels.is_some_and(|ls| ls.iter().any(|(k, _)| k == key))
            }
            // Resync always delivers; unfiltered subscribers take all.
            _ => true,
        };
        !wanted || s.tx.send(ev.clone()).is_ok()
    });
    st.notifiers.retain(|tx| tx.send(()).is_ok());
}

fn apply_event(st: &mut CacheState, ev: WatchEvent) {
    match ev {
        WatchEvent::Added(o) | WatchEvent::Modified(o) => {
            let mut prev_labels = None;
            if let Some(old) = st.objects.get(&o.meta.name) {
                prev_labels = Some(old.meta.labels.clone());
                st.indexes.remove(old);
            }
            st.version = st.version.max(o.meta.resource_version);
            st.indexes.insert(&o);
            st.objects.insert(o.meta.name.clone(), o.clone());
            forward(st, &InformerEvent::Applied(o), prev_labels.as_deref());
        }
        WatchEvent::Deleted(o) => {
            if let Some(old) = st.objects.remove(&o.meta.name) {
                st.indexes.remove(&old);
            }
            // The deleted object carries its own final label set, so no
            // prev is needed for filtered delivery.
            forward(st, &InformerEvent::Deleted(o), None);
        }
    }
}

/// One kind's reflector + cache. Shared through [`Informer`] handles; use
/// [`SharedInformerFactory`] to get one per kind.
pub struct Reflector {
    client: Arc<dyn ApiClient>,
    kind: String,
    page: usize,
    metrics: Metrics,
    state: Mutex<CacheState>,
}

impl Reflector {
    fn new(client: Arc<dyn ApiClient>, kind: &str, page: usize, metrics: Metrics) -> Reflector {
        Reflector {
            client,
            kind: kind.to_string(),
            page: page.max(1),
            metrics,
            state: Mutex::new(CacheState {
                objects: BTreeMap::new(),
                indexes: Indexes::default(),
                version: 0,
                epoch: 0,
                seeded: false,
                rx: None,
                subs: Vec::new(),
                notifiers: Vec::new(),
            }),
        }
    }

    /// Seed (paged list + watch) or re-seed the cache. The watch starts
    /// from the *first* page's version so every event racing the
    /// pagination is replayed afterwards — duplicates upsert idempotently,
    /// and a burst that outruns the history window mid-seed simply ends
    /// the new stream, which the next sync recovers from.
    fn relist(&self, st: &mut CacheState) -> Result<()> {
        // A seeded cache first asks for just the changes since its
        // bookmark; a delta answer keeps the epoch and skips the full
        // list entirely. An error here falls through to the full relist,
        // which reports the transport's real health.
        if st.seeded && st.version > 0 {
            if let Ok(true) = self.delta_relist(st) {
                return Ok(());
            }
        }
        let mut objects: BTreeMap<String, KubeObject> = BTreeMap::new();
        let mut opts = ListOptions::all().with_limit(self.page);
        let mut bookmark = None;
        loop {
            let page = self.client.list(&self.kind, &opts)?;
            bookmark.get_or_insert(page.resource_version);
            for o in page.items {
                objects.insert(o.meta.name.clone(), o);
            }
            match page.continue_token {
                Some(t) => opts = ListOptions::all().with_limit(self.page).continue_from(&t),
                None => break,
            }
        }
        let version = bookmark.unwrap_or(0);
        let rx = self.client.watch(Some(&self.kind), version)?;
        let was_seeded = st.seeded;
        st.objects = objects;
        {
            // Split borrow: reindex over the object map without cloning.
            let CacheState { objects: cached, indexes, .. } = &mut *st;
            indexes.rebuild(cached);
        }
        st.version = version;
        st.rx = Some(rx);
        st.seeded = true;
        self.metrics.inc("kube.informer.lists");
        if was_seeded {
            // 410 recovery: events may be lost — tell subscribers to
            // rebuild derived state from the cache.
            st.epoch += 1;
            self.metrics.inc("kube.informer.resyncs");
            let epoch = st.epoch;
            forward(st, &InformerEvent::Resync { epoch }, None);
        } else if !st.subs.is_empty() {
            // Initial seed: subscribers that registered before the seed
            // see every existing object exactly once, like a replay.
            // Skipped entirely when nobody is listening — a seed must not
            // pay an O(objects) clone for an empty audience.
            let objs: Vec<KubeObject> = st.objects.values().cloned().collect();
            for o in objs {
                forward(st, &InformerEvent::Applied(o), None);
            }
        } else if !st.objects.is_empty() {
            // Wake notify-only listeners once for the whole seed.
            st.notifiers.retain(|tx| tx.send(()).is_ok());
        }
        Ok(())
    }

    /// Try to recover a lost stream with a delta list from the current
    /// bookmark. `Ok(true)`: the server's window covered the bookmark —
    /// missed changes were applied as ordinary events (subscribers see
    /// them, the epoch does not move) and a fresh watch is installed.
    /// `Ok(false)`: out of window; the caller must full-relist.
    fn delta_relist(&self, st: &mut CacheState) -> Result<bool> {
        let resp = self.client.list(&self.kind, &ListOptions::all().delta_since(st.version))?;
        if !resp.delta {
            return Ok(false);
        }
        for name in &resp.deleted {
            // A deletion of an object the cache never held is a no-op.
            if let Some(old) = st.objects.get(name).cloned() {
                apply_event(st, WatchEvent::Deleted(old));
            }
        }
        for o in resp.items {
            apply_event(st, WatchEvent::Modified(o));
        }
        st.version = st.version.max(resp.resource_version);
        st.rx = Some(self.client.watch(Some(&self.kind), st.version)?);
        self.metrics.inc("kube.informer.delta_relists");
        Ok(true)
    }

    fn sync(&self) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if !st.seeded || st.rx.is_none() {
            self.relist(&mut st)?;
        }
        loop {
            let next = match &st.rx {
                Some(rx) => rx.try_recv(),
                None => break,
            };
            match next {
                Ok(ev) => {
                    self.metrics.inc("kube.informer.events");
                    // Rejoin the originating write's trace (the object's
                    // `hpcorc.io/trace` annotation rode through store →
                    // WAL → watch), so cache apply + fan-out shows up in
                    // the same causal tree as the create that caused it.
                    let parent = ev
                        .object()
                        .meta
                        .annotation(crate::obs::TRACE_ANNOTATION)
                        .and_then(crate::obs::TraceContext::parse_wire);
                    let _span = crate::obs::span_with_parent(
                        "informer",
                        &format!("deliver {}", self.kind),
                        parent,
                    );
                    let t0 = Instant::now();
                    apply_event(&mut st, ev);
                    self.metrics
                        .observe("kube.informer.deliver_ns", t0.elapsed().as_nanos() as u64);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // Stream lost: remote restart, or the bookmark fell
                    // out of the retained history window (410 Gone).
                    st.rx = None;
                    self.relist(&mut st)?;
                }
            }
        }
        Ok(())
    }
}

/// A shared per-kind read handle over a [`Reflector`]. Cheap to clone;
/// all clones (and all handles from the same factory) share one cache.
#[derive(Clone)]
pub struct Informer {
    inner: Arc<Reflector>,
}

impl Informer {
    /// A standalone informer (its own reflector). Prefer
    /// [`SharedInformerFactory::informer`] so consumers share caches.
    pub fn standalone(client: Arc<dyn ApiClient>, kind: &str, metrics: Metrics) -> Informer {
        Informer { inner: Arc::new(Reflector::new(client, kind, DEFAULT_LIST_PAGE, metrics)) }
    }

    pub fn kind(&self) -> &str {
        &self.inner.kind
    }

    /// Drain pending watch events into the cache (seeding first if
    /// needed). Synchronous and idempotent: the deterministic-stepping
    /// entry point, also called by the factory pump thread. On transport
    /// failure the cache keeps its last-good state and the error
    /// propagates; the next sync retries.
    pub fn sync(&self) -> Result<()> {
        self.inner.sync()
    }

    /// Cached object by name.
    pub fn get(&self, name: &str) -> Option<KubeObject> {
        self.inner.state.lock().unwrap().objects.get(name).cloned()
    }

    /// All cached objects (cloned). For hot paths prefer
    /// [`Informer::read`] (no clones) or an indexed read.
    pub fn list(&self) -> Vec<KubeObject> {
        self.inner.state.lock().unwrap().objects.values().cloned().collect()
    }

    /// Cached names (the runner's resync diff primitive).
    pub fn names(&self) -> Vec<String> {
        self.inner.state.lock().unwrap().objects.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().objects.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Objects carrying `key=value` (label index).
    pub fn list_labelled(&self, key: &str, value: &str) -> Vec<KubeObject> {
        let st = self.inner.state.lock().unwrap();
        st.indexes
            .by_label
            .get(&(key.to_string(), value.to_string()))
            .map(|names| names.iter().filter_map(|n| st.objects.get(n).cloned()).collect())
            .unwrap_or_default()
    }

    /// Objects carrying the label `key` with any value (what lets kueue
    /// scan only queue-labelled workloads).
    pub fn list_with_label_key(&self, key: &str) -> Vec<KubeObject> {
        let st = self.inner.state.lock().unwrap();
        st.indexes
            .by_label_key
            .get(key)
            .map(|names| names.iter().filter_map(|n| st.objects.get(n).cloned()).collect())
            .unwrap_or_default()
    }

    /// Objects owned by (kind, name) — the ownership index the cascade
    /// walks server-side, available client-side for free.
    pub fn list_owned_by(&self, kind: &str, name: &str) -> Vec<KubeObject> {
        let st = self.inner.state.lock().unwrap();
        st.indexes
            .by_owner
            .get(&(kind.to_string(), name.to_string()))
            .map(|names| names.iter().filter_map(|n| st.objects.get(n).cloned()).collect())
            .unwrap_or_default()
    }

    /// Register a field path (e.g. `spec.nodeName`) for O(matching) reads
    /// through [`Informer::list_by_field`]. Idempotent; reindexes the
    /// current cache.
    pub fn ensure_field_index(&self, path: &str) {
        let mut st = self.inner.state.lock().unwrap();
        if st.indexes.field_paths.iter().any(|p| p == path) {
            return;
        }
        st.indexes.field_paths.push(path.to_string());
        let CacheState { objects, indexes, .. } = &mut *st;
        for o in objects.values() {
            if let Some(val) = field_value(o, path) {
                indexes
                    .by_field
                    .entry((path.to_string(), val))
                    .or_default()
                    .insert(o.meta.name.clone());
            }
        }
    }

    /// Objects whose `path` renders to `value`. Indexed when the path was
    /// registered via [`Informer::ensure_field_index`]; otherwise a cache
    /// scan with full [`ListOptions`] field-selector semantics (correct,
    /// just not O(matching)).
    pub fn list_by_field(&self, path: &str, value: &str) -> Vec<KubeObject> {
        let st = self.inner.state.lock().unwrap();
        if st.indexes.field_paths.iter().any(|p| p == path) {
            return st
                .indexes
                .by_field
                .get(&(path.to_string(), value.to_string()))
                .map(|names| names.iter().filter_map(|n| st.objects.get(n).cloned()).collect())
                .unwrap_or_default();
        }
        let opts = ListOptions::all().with_field(path, value);
        st.objects.values().filter(|o| opts.matches_fields(o)).cloned().collect()
    }

    /// Zero-copy scan: run `f` over the cached name→object map under the
    /// cache lock. `f` must not call back into this informer or block —
    /// decode what you need and return owned data.
    pub fn read<R>(&self, f: impl FnOnce(&BTreeMap<String, KubeObject>) -> R) -> R {
        let st = self.inner.state.lock().unwrap();
        f(&st.objects)
    }

    /// Subscribe to cache deltas. The current cache is replayed as
    /// `Applied` events first (so a late subscriber misses nothing), then
    /// live events stream as they are drained by [`Informer::sync`].
    pub fn subscribe(&self) -> Receiver<InformerEvent> {
        let (tx, rx) = channel();
        self.subscribe_with(tx);
        rx
    }

    /// Like [`Informer::subscribe`] but feeding a caller-supplied sender —
    /// what lets one consumer multiplex several kinds' events into a
    /// single channel.
    pub fn subscribe_with(&self, tx: Sender<InformerEvent>) {
        let mut st = self.inner.state.lock().unwrap();
        for o in st.objects.values() {
            let _ = tx.send(InformerEvent::Applied(o.clone()));
        }
        st.subs.push(Subscriber { tx, label_key: None });
    }

    /// Subscription restricted to objects carrying `label_key` (replay
    /// and deltas alike; `Resync` always delivers). The cheap way to
    /// watch a labelled subset of a high-churn kind: unlabelled events
    /// are dropped inside the reflector, before any clone. An object
    /// whose key is *removed* still delivers that one transition (the
    /// event object no longer carries the key), so derived state can
    /// release what it charged — only objects that never carried the key
    /// are invisible.
    pub fn subscribe_with_label_key(&self, tx: Sender<InformerEvent>, label_key: &str) {
        let mut st = self.inner.state.lock().unwrap();
        for o in st.objects.values() {
            if o.meta.labels.iter().any(|(k, _)| k == label_key) {
                let _ = tx.send(InformerEvent::Applied(o.clone()));
            }
        }
        st.subs.push(Subscriber { tx, label_key: Some(label_key.to_string()) });
    }

    /// Payload-free wake-up subscription: one `()` per cache event (and
    /// one when an initial seed lands), never an object clone — for
    /// consumers that treat events purely as "run a cycle now" signals
    /// (the scheduler). An existing non-empty cache pings once at
    /// registration so a late subscriber doesn't sleep through state it
    /// has never examined.
    pub fn subscribe_notify(&self, tx: Sender<()>) {
        let mut st = self.inner.state.lock().unwrap();
        if !st.objects.is_empty() {
            let _ = tx.send(());
        }
        st.notifiers.push(tx);
    }

    /// Resync epoch: bumped every time the reflector relisted after
    /// losing its stream. Event-derived state must rebuild when this
    /// moves.
    pub fn epoch(&self) -> u64 {
        self.inner.state.lock().unwrap().epoch
    }

    /// Store version the cache has caught up to.
    pub fn resource_version(&self) -> u64 {
        self.inner.state.lock().unwrap().version
    }
}

struct FactoryInner {
    client: Arc<dyn ApiClient>,
    metrics: Metrics,
    page: usize,
    reflectors: Mutex<BTreeMap<String, Arc<Reflector>>>,
}

/// Hands out one shared [`Informer`] per kind. Every consumer built from
/// the same factory reads the same cache — one watch stream per kind for
/// the whole process, however many control loops consume it.
#[derive(Clone)]
pub struct SharedInformerFactory {
    inner: Arc<FactoryInner>,
}

impl SharedInformerFactory {
    pub fn new(client: Arc<dyn ApiClient>, metrics: Metrics) -> SharedInformerFactory {
        Self::with_page_size(client, metrics, DEFAULT_LIST_PAGE)
    }

    pub fn with_page_size(
        client: Arc<dyn ApiClient>,
        metrics: Metrics,
        page: usize,
    ) -> SharedInformerFactory {
        SharedInformerFactory {
            inner: Arc::new(FactoryInner {
                client,
                metrics,
                page,
                reflectors: Mutex::new(BTreeMap::new()),
            }),
        }
    }

    /// The transport handle consumers write through (informers are the
    /// read path; create/update/delete still go to the API).
    pub fn client(&self) -> Arc<dyn ApiClient> {
        self.inner.client.clone()
    }

    /// The shared informer for `kind` (created lazily, seeded on first
    /// sync).
    pub fn informer(&self, kind: &str) -> Informer {
        let mut reflectors = self.inner.reflectors.lock().unwrap();
        let r = reflectors.entry(kind.to_string()).or_insert_with(|| {
            Arc::new(Reflector::new(
                self.inner.client.clone(),
                kind,
                self.inner.page,
                self.inner.metrics.clone(),
            ))
        });
        Informer { inner: r.clone() }
    }

    /// Sync every registered informer once (deterministic stepping).
    /// Transport errors are logged, not propagated — each reflector keeps
    /// its last-good cache and retries next round.
    pub fn sync_all(&self) {
        let reflectors: Vec<Arc<Reflector>> =
            self.inner.reflectors.lock().unwrap().values().cloned().collect();
        for r in reflectors {
            if let Err(e) = r.sync() {
                crate::warn!("informer", "{} sync failed: {e}", r.kind);
            }
        }
    }

    /// Start the pump: one thread draining every reflector's watch stream
    /// each `period`, which is what pushes events to subscribers while
    /// daemons block on their subscription channels.
    pub fn start(&self, period: Duration, shutdown: Shutdown) {
        let this = self.clone();
        crate::rt::spawn_named("kube-informers", move || loop {
            if shutdown.wait_timeout(period) {
                return;
            }
            this.sync_all();
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resources;
    use crate::kube::api::{NodeView, PodView, KIND_NODE, KIND_POD};
    use crate::kube::apiserver::ApiServer;
    use crate::kube::client::ObjectList;

    fn api() -> ApiServer {
        ApiServer::new(Metrics::new())
    }

    fn pod(name: &str) -> KubeObject {
        PodView::build(name, "img.sif", Resources::new(100, 1 << 20, 0), &[])
    }

    #[test]
    fn seeds_then_tails_watch() {
        let a = api();
        a.create(pod("a")).unwrap();
        a.create(pod("b")).unwrap();
        let factory = SharedInformerFactory::new(a.client(), Metrics::new());
        let pods = factory.informer(KIND_POD);
        pods.sync().unwrap();
        assert_eq!(pods.len(), 2);
        // Tail: create/update/delete flow in on sync, no relist.
        a.create(pod("c")).unwrap();
        a.update_status(KIND_POD, "a", |o| o.status.insert("phase", "Running")).unwrap();
        a.delete(KIND_POD, "b").unwrap();
        pods.sync().unwrap();
        assert_eq!(pods.len(), 2);
        assert!(pods.get("b").is_none());
        assert_eq!(pods.get("a").unwrap().status.opt_str("phase"), Some("Running"));
        assert_eq!(pods.epoch(), 0, "no stream loss, no resync");
    }

    #[test]
    fn paged_seed_covers_everything() {
        let a = api();
        for i in 0..10 {
            a.create(pod(&format!("p{i}"))).unwrap();
        }
        let factory = SharedInformerFactory::with_page_size(a.client(), Metrics::new(), 3);
        let pods = factory.informer(KIND_POD);
        pods.sync().unwrap();
        assert_eq!(pods.len(), 10, "4 pages of 3 cover all 10");
    }

    #[test]
    fn indexes_label_field_owner() {
        let a = api();
        let mut p = pod("web-0");
        p.meta.set_label("deployment", "web");
        p.meta.owner = Some(("Deployment".to_string(), "web".to_string()));
        p.spec.insert("nodeName", "w1");
        a.create(p).unwrap();
        a.create(pod("lone")).unwrap();

        let factory = SharedInformerFactory::new(a.client(), Metrics::new());
        let pods = factory.informer(KIND_POD);
        pods.ensure_field_index("spec.nodeName");
        pods.sync().unwrap();

        assert_eq!(pods.list_labelled("deployment", "web").len(), 1);
        assert_eq!(pods.list_with_label_key("deployment").len(), 1);
        assert_eq!(pods.list_owned_by("Deployment", "web").len(), 1);
        assert_eq!(pods.list_by_field("spec.nodeName", "w1").len(), 1);
        assert!(pods.list_by_field("spec.nodeName", "w2").is_empty());
        // Unindexed path falls back to a correct scan.
        assert_eq!(pods.list_by_field("status.phase", "Pending").len(), 2);

        // Rebind: the field index follows the mutation.
        a.update_status(KIND_POD, "web-0", |o| o.spec.insert("nodeName", "w2")).unwrap();
        pods.sync().unwrap();
        assert!(pods.list_by_field("spec.nodeName", "w1").is_empty());
        assert_eq!(pods.list_by_field("spec.nodeName", "w2").len(), 1);
        // Delete: every index forgets the object.
        a.delete(KIND_POD, "web-0").unwrap();
        pods.sync().unwrap();
        assert!(pods.list_labelled("deployment", "web").is_empty());
        assert!(pods.list_owned_by("Deployment", "web").is_empty());
        assert!(pods.list_by_field("spec.nodeName", "w2").is_empty());
    }

    #[test]
    fn subscription_replays_then_streams() {
        let a = api();
        a.create(pod("pre")).unwrap();
        let factory = SharedInformerFactory::new(a.client(), Metrics::new());
        let pods = factory.informer(KIND_POD);
        pods.sync().unwrap();
        let rx = pods.subscribe();
        // Replay of the existing cache.
        match rx.try_recv().unwrap() {
            InformerEvent::Applied(o) => assert_eq!(o.meta.name, "pre"),
            other => panic!("expected replay, got {other:?}"),
        }
        // Live events.
        a.create(pod("live")).unwrap();
        a.delete(KIND_POD, "live").unwrap();
        pods.sync().unwrap();
        let evs: Vec<InformerEvent> = rx.try_iter().collect();
        assert_eq!(evs.len(), 2);
        assert!(matches!(&evs[0], InformerEvent::Applied(o) if o.meta.name == "live"));
        assert!(matches!(&evs[1], InformerEvent::Deleted(o) if o.meta.name == "live"));
    }

    #[test]
    fn filtered_and_notify_subscriptions() {
        let a = api();
        let mut labelled = pod("queued");
        labelled.meta.set_label("kueue.x-k8s.io/queue-name", "team");
        a.create(labelled).unwrap();
        a.create(pod("plain")).unwrap();
        let factory = SharedInformerFactory::new(a.client(), Metrics::new());
        let pods = factory.informer(KIND_POD);
        pods.sync().unwrap();

        // Label-key filter: replay and deltas only for labelled objects.
        let (tx, rx) = channel();
        pods.subscribe_with_label_key(tx, "kueue.x-k8s.io/queue-name");
        let replay: Vec<InformerEvent> = rx.try_iter().collect();
        assert_eq!(replay.len(), 1, "only the labelled pod replays");
        a.create(pod("plain2")).unwrap();
        let mut labelled2 = pod("queued2");
        labelled2.meta.set_label("kueue.x-k8s.io/queue-name", "team");
        a.create(labelled2).unwrap();
        pods.sync().unwrap();
        let evs: Vec<InformerEvent> = rx.try_iter().collect();
        assert_eq!(evs.len(), 1, "unlabelled churn is dropped pre-clone");
        assert_eq!(evs[0].object().unwrap().meta.name, "queued2");

        // Notify-only: one () per event, one at registration (cache
        // non-empty), never an object.
        let (ntx, nrx) = channel();
        pods.subscribe_notify(ntx);
        assert!(nrx.try_recv().is_ok(), "non-empty cache pings at registration");
        a.create(pod("another")).unwrap();
        pods.sync().unwrap();
        assert!(nrx.try_recv().is_ok(), "events ping the notifier");
        assert!(nrx.try_recv().is_err(), "exactly one ping per event");
    }

    #[test]
    fn factory_shares_one_cache_per_kind() {
        let a = api();
        a.create(pod("p")).unwrap();
        let factory = SharedInformerFactory::new(a.client(), Metrics::new());
        let h1 = factory.informer(KIND_POD);
        let h2 = factory.informer(KIND_POD);
        h1.sync().unwrap();
        // h2 sees h1's sync: same reflector underneath.
        assert_eq!(h2.len(), 1);
        a.create(NodeView::build("n", Resources::cores(1, 1 << 30), &[])).unwrap();
        let nodes = factory.informer(KIND_NODE);
        nodes.sync().unwrap();
        assert_eq!(nodes.len(), 1);
        assert_eq!(h2.len(), 1, "kinds are isolated");
    }

    /// An ApiClient wrapper whose watch streams can be severed on demand
    /// — the deterministic stand-in for a remote server restart or a
    /// bookmark falling out of the history window.
    struct KillableApi {
        api: ApiServer,
        taps: Mutex<Vec<Shutdown>>,
    }

    impl KillableApi {
        fn kill_streams(&self) {
            for sd in self.taps.lock().unwrap().drain(..) {
                sd.trigger();
            }
        }
    }

    impl ApiClient for KillableApi {
        fn create(&self, obj: KubeObject) -> Result<KubeObject> {
            self.api.create(obj)
        }
        fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
            self.api.get(kind, name)
        }
        fn update(&self, obj: KubeObject) -> Result<KubeObject> {
            ApiServer::update(&self.api, obj)
        }
        fn update_status(
            &self,
            kind: &str,
            name: &str,
            f: &dyn Fn(&mut KubeObject),
        ) -> Result<KubeObject> {
            self.api.update_status(kind, name, f)
        }
        fn patch_merge(&self, kind: &str, name: &str, patch: &Value) -> Result<KubeObject> {
            self.api.patch_merge(kind, name, patch)
        }
        fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
            self.api.delete(kind, name)
        }
        fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
            self.api.apply(obj)
        }
        fn list(&self, kind: &str, opts: &ListOptions) -> Result<ObjectList> {
            self.api.list_opts(kind, opts)
        }
        fn watch(&self, kind: Option<&str>, from: u64) -> Result<Receiver<WatchEvent>> {
            let upstream = ApiServer::watch(&self.api, kind, from);
            let (tx, rx) = channel();
            let sd = Shutdown::new();
            self.taps.lock().unwrap().push(sd.clone());
            crate::rt::spawn_named("killable-watch", move || loop {
                if sd.is_triggered() {
                    return; // drops tx: stream severed
                }
                match upstream.recv_timeout(Duration::from_millis(1)) {
                    Ok(ev) => {
                        if tx.send(ev).is_err() {
                            return;
                        }
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                    Err(_) => return,
                }
            });
            Ok(rx)
        }
        fn server_time_s(&self) -> Result<f64> {
            Ok(self.api.now_s())
        }
    }

    #[test]
    fn stream_loss_relists_and_bumps_epoch() {
        // History cap 4: the churn below overflows the pod shard's
        // retained window, so the delta path reports out-of-window and
        // the reflector must take the full-relist (410-Gone) road.
        let killable = Arc::new(KillableApi {
            api: ApiServer::with_history_cap(Metrics::new(), 4),
            taps: Mutex::new(Vec::new()),
        });
        killable.api.create(pod("before")).unwrap();
        let factory =
            SharedInformerFactory::new(killable.clone() as Arc<dyn ApiClient>, Metrics::new());
        let pods = factory.informer(KIND_POD);
        pods.sync().unwrap();
        let rx = pods.subscribe();
        let _ = rx.try_iter().count(); // drain the replay
        assert_eq!(pods.epoch(), 0);

        // Sever the stream, then change the world while the informer is
        // blind — more events than the window retains.
        killable.kill_streams();
        killable.api.delete(KIND_POD, "before").unwrap();
        killable.api.create(pod("after")).unwrap();
        for i in 0..4 {
            killable.api.create(pod(&format!("filler{i}"))).unwrap();
        }
        // Give the severed forwarder a beat to drop its sender.
        std::thread::sleep(Duration::from_millis(10));

        pods.sync().unwrap();
        assert_eq!(pods.epoch(), 1, "out-of-window relist bumps the resync epoch");
        assert!(pods.get("before").is_none(), "missed delete recovered by relist");
        assert!(pods.get("after").is_some(), "missed create recovered by relist");
        let evs: Vec<InformerEvent> = rx.try_iter().collect();
        assert!(
            evs.iter().any(|e| matches!(e, InformerEvent::Resync { epoch: 1 })),
            "subscribers told to rebuild: {evs:?}"
        );
        // The fresh stream tails normally again.
        killable.api.create(pod("later")).unwrap();
        pods.sync().unwrap();
        assert!(pods.get("later").is_some());
        assert_eq!(pods.epoch(), 1, "healthy stream does not resync");
    }

    #[test]
    fn stream_loss_inside_window_delta_relists_without_resync() {
        let killable = Arc::new(KillableApi { api: api(), taps: Mutex::new(Vec::new()) });
        killable.api.create(pod("before")).unwrap();
        let metrics = Metrics::new();
        let factory =
            SharedInformerFactory::new(killable.clone() as Arc<dyn ApiClient>, metrics.clone());
        let pods = factory.informer(KIND_POD);
        pods.sync().unwrap();
        let rx = pods.subscribe();
        let _ = rx.try_iter().count();

        // Sever the stream; the default window easily retains the gap.
        killable.kill_streams();
        killable.api.delete(KIND_POD, "before").unwrap();
        killable.api.create(pod("after")).unwrap();
        std::thread::sleep(Duration::from_millis(10));

        pods.sync().unwrap();
        assert_eq!(pods.epoch(), 0, "delta recovery must not bump the epoch");
        assert!(pods.get("before").is_none());
        assert!(pods.get("after").is_some());
        let evs: Vec<InformerEvent> = rx.try_iter().collect();
        assert!(
            !evs.iter().any(|e| matches!(e, InformerEvent::Resync { .. })),
            "no Resync on delta recovery: {evs:?}"
        );
        assert!(
            evs.iter()
                .any(|e| matches!(e, InformerEvent::Deleted(o) if o.meta.name == "before")),
            "missed delete surfaces as an ordinary event: {evs:?}"
        );
        assert!(
            evs.iter()
                .any(|e| matches!(e, InformerEvent::Applied(o) if o.meta.name == "after")),
            "missed create surfaces as an ordinary event: {evs:?}"
        );
        assert_eq!(
            metrics.counter("kube.informer.delta_relists").load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(
            metrics.counter("kube.informer.resyncs").load(std::sync::atomic::Ordering::Relaxed),
            0
        );
        // The fresh stream tails live events again.
        killable.api.create(pod("later")).unwrap();
        pods.sync().unwrap();
        assert!(pods.get("later").is_some());
    }

    #[test]
    fn label_removal_delivers_to_filtered_subscribers() {
        let a = api();
        let mut labelled = pod("charged");
        labelled.meta.set_label("kueue.x-k8s.io/queue-name", "team");
        a.create(labelled).unwrap();
        let factory = SharedInformerFactory::new(a.client(), Metrics::new());
        let pods = factory.informer(KIND_POD);
        pods.sync().unwrap();
        let (tx, rx) = channel();
        pods.subscribe_with_label_key(tx, "kueue.x-k8s.io/queue-name");
        let _ = rx.try_iter().count(); // drain the replay

        // Strip the queue label: the transition must still deliver (the
        // event object no longer carries the key) so ledgers can uncharge.
        let mut stripped = a.get(KIND_POD, "charged").unwrap();
        stripped.meta.labels.retain(|(k, _)| k != "kueue.x-k8s.io/queue-name");
        a.update(stripped).unwrap();
        pods.sync().unwrap();
        let evs: Vec<InformerEvent> = rx.try_iter().collect();
        assert_eq!(evs.len(), 1, "the removal transition delivers: {evs:?}");
        let o = evs[0].object().unwrap();
        assert_eq!(o.meta.name, "charged");
        assert!(
            !o.meta.labels.iter().any(|(k, _)| k == "kueue.x-k8s.io/queue-name"),
            "subscriber sees the post-removal object"
        );

        // Subsequent churn on the now-unlabelled object is filtered again.
        a.update_status(KIND_POD, "charged", |o| o.status.insert("phase", "Running")).unwrap();
        pods.sync().unwrap();
        assert!(rx.try_iter().next().is_none(), "steady unlabelled churn stays dropped");
    }

    #[test]
    fn read_scans_without_cloning() {
        let a = api();
        for i in 0..5 {
            a.create(pod(&format!("p{i}"))).unwrap();
        }
        let factory = SharedInformerFactory::new(a.client(), Metrics::new());
        let pods = factory.informer(KIND_POD);
        pods.sync().unwrap();
        let pending = pods.read(|objs| {
            objs.values()
                .filter(|o| o.status.opt_str("phase").unwrap_or("Pending") == "Pending")
                .count()
        });
        assert_eq!(pending, 5);
    }
}
