//! Simulated shared filesystem ($HOME on the clusters).
//!
//! The paper's flow stages job output files (`$HOME/low.out`) between the
//! Torque side and the Kubernetes side via a shared directory. We model a
//! cluster-wide shared FS as an in-memory path→bytes map with `$HOME` and
//! `$PATH`-style variable expansion, plus an optional mirror onto a real
//! temp directory for the CLI/examples to inspect.

use crate::util::{Error, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Cluster-shared filesystem handle (clone = same FS, like NFS mounts).
#[derive(Clone, Default)]
pub struct SharedFs {
    inner: Arc<Mutex<FsInner>>,
}

#[derive(Default)]
struct FsInner {
    files: BTreeMap<String, Vec<u8>>,
    /// Environment used for path expansion ($HOME etc.).
    env: BTreeMap<String, String>,
    /// Optional real-directory mirror root.
    mirror: Option<std::path::PathBuf>,
}

impl SharedFs {
    pub fn new() -> Self {
        let fs = SharedFs::default();
        fs.set_env("HOME", "/home/user");
        fs
    }

    pub fn set_env(&self, key: &str, val: &str) {
        self.inner.lock().unwrap().env.insert(key.to_string(), val.to_string());
    }

    pub fn env(&self, key: &str) -> Option<String> {
        self.inner.lock().unwrap().env.get(key).cloned()
    }

    /// Mirror writes into a real directory (for human inspection in examples).
    pub fn set_mirror(&self, dir: impl Into<std::path::PathBuf>) {
        self.inner.lock().unwrap().mirror = Some(dir.into());
    }

    /// Expand `$VAR` and `${VAR}` references using the FS environment.
    pub fn expand(&self, path: &str) -> String {
        let env = &self.inner.lock().unwrap().env;
        expand_vars(path, |k| env.get(k).cloned())
    }

    /// Normalize: expand vars, collapse `//`, strip trailing `/` (dirs keep it).
    fn norm(&self, path: &str) -> String {
        let p = self.expand(path);
        let mut out = String::with_capacity(p.len());
        let mut prev_slash = false;
        for c in p.chars() {
            if c == '/' {
                if !prev_slash {
                    out.push(c);
                }
                prev_slash = true;
            } else {
                prev_slash = false;
                out.push(c);
            }
        }
        out
    }

    pub fn write(&self, path: &str, data: impl AsRef<[u8]>) -> Result<()> {
        let key = self.norm(path);
        if key.is_empty() || key.ends_with('/') {
            return Err(Error::Io(format!("invalid file path `{path}`")));
        }
        let mut inner = self.inner.lock().unwrap();
        inner.files.insert(key.clone(), data.as_ref().to_vec());
        if let Some(root) = inner.mirror.clone() {
            let rel = key.trim_start_matches('/');
            let real = root.join(rel);
            if let Some(parent) = real.parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            let _ = std::fs::write(real, data.as_ref());
        }
        Ok(())
    }

    pub fn append(&self, path: &str, data: impl AsRef<[u8]>) -> Result<()> {
        let key = self.norm(path);
        let mut inner = self.inner.lock().unwrap();
        inner.files.entry(key.clone()).or_default().extend_from_slice(data.as_ref());
        if let Some(root) = inner.mirror.clone() {
            let content = inner.files.get(&key).cloned().unwrap_or_default();
            let rel = key.trim_start_matches('/');
            let real = root.join(rel);
            if let Some(parent) = real.parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            let _ = std::fs::write(real, content);
        }
        Ok(())
    }

    pub fn read(&self, path: &str) -> Result<Vec<u8>> {
        let key = self.norm(path);
        self.inner
            .lock()
            .unwrap()
            .files
            .get(&key)
            .cloned()
            .ok_or_else(|| Error::Io(format!("no such file: {key}")))
    }

    pub fn read_string(&self, path: &str) -> Result<String> {
        String::from_utf8(self.read(path)?)
            .map_err(|_| Error::Io(format!("not utf-8: {path}")))
    }

    pub fn exists(&self, path: &str) -> bool {
        let key = self.norm(path);
        self.inner.lock().unwrap().files.contains_key(&key)
    }

    pub fn remove(&self, path: &str) -> bool {
        let key = self.norm(path);
        self.inner.lock().unwrap().files.remove(&key).is_some()
    }

    /// Copy a file within the shared FS (results staging).
    pub fn copy(&self, from: &str, to: &str) -> Result<()> {
        let data = self.read(from)?;
        // If `to` is a directory path (ends with /), keep the source basename.
        let to_norm = self.norm(to);
        let target = if to_norm.ends_with('/') {
            let base = self.norm(from);
            let base = base.rsplit('/').next().unwrap_or("out");
            format!("{to_norm}{base}")
        } else {
            to_norm
        };
        self.write(&target, data)
    }

    /// List files under a prefix (sorted).
    pub fn list(&self, prefix: &str) -> Vec<String> {
        let p = self.norm(prefix);
        self.inner
            .lock()
            .unwrap()
            .files
            .keys()
            .filter(|k| k.starts_with(&p))
            .cloned()
            .collect()
    }
}

/// `$VAR` / `${VAR}` expansion; unknown vars are left intact.
pub fn expand_vars(s: &str, lookup: impl Fn(&str) -> Option<String>) -> String {
    let bytes = s.as_bytes();
    let mut out = String::with_capacity(s.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'$' && i + 1 < bytes.len() {
            let (name, consumed) = if bytes[i + 1] == b'{' {
                if let Some(end) = s[i + 2..].find('}') {
                    (&s[i + 2..i + 2 + end], end + 3)
                } else {
                    ("", 0)
                }
            } else {
                let rest = &s[i + 1..];
                let len = rest
                    .char_indices()
                    .take_while(|(_, c)| c.is_ascii_alphanumeric() || *c == '_')
                    .map(|(j, c)| j + c.len_utf8())
                    .last()
                    .unwrap_or(0);
                (&rest[..len], len + 1)
            };
            if !name.is_empty() {
                if let Some(v) = lookup(name) {
                    out.push_str(&v);
                    i += consumed;
                    continue;
                }
            }
        }
        let c = s[i..].chars().next().unwrap();
        out.push(c);
        i += c.len_utf8();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let fs = SharedFs::new();
        fs.write("/home/user/low.out", b"moo").unwrap();
        assert_eq!(fs.read_string("/home/user/low.out").unwrap(), "moo");
        assert!(fs.exists("/home/user/low.out"));
        assert!(!fs.exists("/home/user/other"));
    }

    #[test]
    fn home_expansion() {
        let fs = SharedFs::new();
        fs.write("$HOME/low.out", b"x").unwrap();
        assert!(fs.exists("/home/user/low.out"));
        assert_eq!(fs.read_string("${HOME}/low.out").unwrap(), "x");
    }

    #[test]
    fn copy_into_directory() {
        let fs = SharedFs::new();
        fs.write("$HOME/low.out", b"result").unwrap();
        fs.copy("$HOME/low.out", "$HOME/results/").unwrap();
        assert_eq!(fs.read_string("/home/user/results/low.out").unwrap(), "result");
    }

    #[test]
    fn append_accumulates() {
        let fs = SharedFs::new();
        fs.append("$HOME/log", b"a").unwrap();
        fs.append("$HOME/log", b"b").unwrap();
        assert_eq!(fs.read_string("$HOME/log").unwrap(), "ab");
    }

    #[test]
    fn missing_file_errors() {
        let fs = SharedFs::new();
        assert!(fs.read("/nope").is_err());
        assert!(fs.copy("/nope", "/x").is_err());
        assert!(!fs.remove("/nope"));
    }

    #[test]
    fn list_prefix() {
        let fs = SharedFs::new();
        fs.write("/a/1", b"").unwrap();
        fs.write("/a/2", b"").unwrap();
        fs.write("/b/3", b"").unwrap();
        assert_eq!(fs.list("/a/"), vec!["/a/1".to_string(), "/a/2".to_string()]);
    }

    #[test]
    fn expand_vars_cases() {
        let lk = |k: &str| match k {
            "HOME" => Some("/h".to_string()),
            "PATH" => Some("/bin".to_string()),
            _ => None,
        };
        assert_eq!(expand_vars("$HOME/x", lk), "/h/x");
        assert_eq!(expand_vars("${HOME}/x", lk), "/h/x");
        assert_eq!(expand_vars("$PATH:$PATH", lk), "/bin:/bin");
        assert_eq!(expand_vars("$UNKNOWN/x", lk), "$UNKNOWN/x");
        assert_eq!(expand_vars("no vars", lk), "no vars");
        assert_eq!(expand_vars("trailing $", lk), "trailing $");
    }

    #[test]
    fn double_slash_normalized() {
        let fs = SharedFs::new();
        fs.set_env("HOME", "/home/user/");
        fs.write("$HOME/low.out", b"x").unwrap();
        assert!(fs.exists("/home/user/low.out"));
    }
}
