//! `QueueAdmission`: the queue layer's quota semantics as a composable
//! [`SchedPolicy`] filter for the discrete-event simulator.
//!
//! Wraps any inner policy and only forwards pending jobs whose tenant
//! queue can reserve their *whole* demand right now — the same
//! nominal/borrowing/cohort arithmetic as the live admission controller
//! (it literally runs [`crate::kueue::Ledger`]), so E1-style experiments
//! can compare an admitted trace against the raw trace under identical
//! placement policies. Jobs without a queue bypass admission, and
//! unknown queue names stay held (exactly the live behaviour).
//!
//! Scope: admission + borrowing only. Preemption of *running* sim jobs
//! would need engine support for requeueing and is out of scope — the
//! live-path integration tests in `tests/kueue.rs` cover eviction.

use crate::kueue::{ClusterQueueView, Ledger, QueueOrdering, QueueResources};
use crate::sched::{Assignment, NodeState, PendingJob, RunningJob, SchedPolicy};
use std::collections::HashMap;
use std::sync::Mutex;

pub struct QueueAdmission {
    queues: Vec<ClusterQueueView>,
    inner: Box<dyn SchedPolicy>,
    name: &'static str,
    /// job id → (queue, demand), remembered so running jobs (which only
    /// carry id + placement) keep their quota charged. Pruned to live
    /// ids every cycle.
    seen: Mutex<HashMap<u64, (String, QueueResources)>>,
}

impl QueueAdmission {
    pub fn new(queues: Vec<ClusterQueueView>, inner: Box<dyn SchedPolicy>) -> QueueAdmission {
        // Leaked once per constructed policy (CLI/bench lifetime) so the
        // composed name can satisfy SchedPolicy's &'static str contract.
        let name = Box::leak(format!("kueue+{}", inner.name()).into_boxed_str());
        QueueAdmission { queues, inner, name, seen: Mutex::new(HashMap::new()) }
    }

    fn demand(job: &PendingJob) -> QueueResources {
        QueueResources {
            nodes: job.nodes,
            cpu_milli: job.nodes as u64 * job.ppn as u64 * 1000,
            mem_bytes: job.nodes as u64 * job.mem,
        }
    }
}

impl SchedPolicy for QueueAdmission {
    fn name(&self) -> &'static str {
        self.name
    }

    fn schedule(
        &self,
        now_s: f64,
        pending: &[PendingJob],
        nodes: &[NodeState],
        running: &[RunningJob],
    ) -> Vec<Assignment> {
        let mut seen = self.seen.lock().unwrap();
        for job in pending {
            if let Some(q) = &job.queue {
                // Overwrite, don't or_insert: a pending job is by
                // definition not running, so refreshing is always safe —
                // and it keeps the map correct when one QueueAdmission is
                // reused across simulate() runs whose job ids collide.
                seen.insert(job.id, (q.clone(), Self::demand(job)));
            }
        }
        seen.retain(|id, _| {
            pending.iter().any(|j| j.id == *id) || running.iter().any(|r| r.id == *id)
        });

        // Charge running jobs' demand to their queues.
        let mut ledger = Ledger::new(self.queues.clone());
        for r in running {
            if let Some((q, d)) = seen.get(&r.id) {
                ledger.charge(q, d);
            }
        }

        // Admit per queue in its configured order, strictly: a blocked
        // gang holds everything behind it in the same queue.
        let mut admitted: Vec<PendingJob> = Vec::new();
        for cq in &self.queues {
            let mut queue_jobs: Vec<&PendingJob> = pending
                .iter()
                .filter(|j| j.queue.as_deref() == Some(cq.name.as_str()))
                .collect();
            match cq.ordering {
                QueueOrdering::Fifo => queue_jobs.sort_by(|a, b| {
                    a.submit_s
                        .partial_cmp(&b.submit_s)
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.id.cmp(&b.id))
                }),
                QueueOrdering::Priority => queue_jobs.sort_by(|a, b| {
                    b.priority.cmp(&a.priority).then(a.id.cmp(&b.id))
                }),
            }
            for job in queue_jobs {
                let demand = Self::demand(job);
                if ledger.fit(&cq.name, &demand).admissible() {
                    ledger.charge(&cq.name, &demand);
                    admitted.push(job.clone());
                } else {
                    break;
                }
            }
        }
        // Unqueued jobs bypass admission; unknown queue names stay held.
        admitted.extend(pending.iter().filter(|j| j.queue.is_none()).cloned());
        drop(seen);
        self.inner.schedule(now_s, &admitted, nodes, running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kueue::PreemptionPolicy;
    use crate::sched::FifoPolicy;
    use crate::sim::{simulate, SimParams};
    use crate::workload::{Trace, TraceJob};

    fn cq(name: &str, cohort: Option<&str>, nodes: u32) -> ClusterQueueView {
        ClusterQueueView::from_object(&ClusterQueueView::build_full(
            name,
            cohort,
            QueueResources::nodes(nodes),
            None,
            QueueOrdering::Fifo,
            PreemptionPolicy::default(),
        ))
        .unwrap()
    }

    fn tenant_job(id: u64, arrival: f64, nodes: u32, runtime: f64, queue: &str) -> TraceJob {
        let mut j = TraceJob::sleep(id, arrival, nodes, 1, runtime * 2.0, runtime);
        j.queue = Some(queue.to_string());
        j
    }

    fn params(nodes: usize) -> SimParams {
        SimParams { nodes, cores_per_node: 1, ..SimParams::default() }
    }

    #[test]
    fn quota_caps_concurrent_tenant_usage() {
        // 4 physical nodes; tenant-a's quota is 1 node. Four 1-node jobs
        // arrive at once: raw FIFO runs them all in parallel, admitted
        // FIFO serializes them behind the quota.
        let jobs: Vec<TraceJob> =
            (0..4).map(|i| tenant_job(i + 1, 0.0, 1, 100.0, "tenant-a")).collect();
        let trace = Trace::new("t", jobs);
        let raw = simulate(&trace, &params(4), &FifoPolicy);
        let admitted = QueueAdmission::new(vec![cq("tenant-a", None, 1)], Box::new(FifoPolicy));
        let metered = simulate(&trace, &params(4), &admitted);
        assert_eq!(raw.completed, 4);
        assert_eq!(metered.completed, 4, "quota delays, never starves");
        assert!((raw.makespan_s - 100.0).abs() < 1e-6);
        assert!(
            (metered.makespan_s - 400.0).abs() < 1e-6,
            "1-node quota serializes: got {}",
            metered.makespan_s
        );
    }

    #[test]
    fn gang_admission_is_atomic() {
        // 2-node gang against a 1-node quota: never admitted; a later
        // 1-node job in the same queue is held behind it (strict FIFO),
        // while an unqueued job flows freely.
        let mut gang = tenant_job(1, 0.0, 2, 50.0, "tenant-a");
        gang.walltime_s = 60.0;
        let follower = tenant_job(2, 1.0, 1, 50.0, "tenant-a");
        let free = TraceJob::sleep(3, 2.0, 1, 1, 100.0, 50.0);
        let trace = Trace::new("t", vec![gang, follower, free]);
        let admitted = QueueAdmission::new(vec![cq("tenant-a", None, 1)], Box::new(FifoPolicy));
        let r = simulate(&trace, &params(4), &admitted);
        assert_eq!(r.completed, 1, "only the unqueued job ran");
        assert_eq!(r.killed_walltime, 2, "gang + follower dropped as never-runnable");
    }

    #[test]
    fn cohort_borrowing_uses_idle_peer_quota() {
        // a and b pool 2+2 nodes. b idle: a's 3-node gang borrows and
        // runs; without the cohort it would never be admitted.
        let trace = Trace::new("t", vec![tenant_job(1, 0.0, 3, 50.0, "tenant-a")]);
        let pooled = QueueAdmission::new(
            vec![cq("tenant-a", Some("pool"), 2), cq("tenant-b", Some("pool"), 2)],
            Box::new(FifoPolicy),
        );
        let r = simulate(&trace, &params(4), &pooled);
        assert_eq!(r.completed, 1, "borrowed idle cohort capacity");
        let solo = QueueAdmission::new(vec![cq("tenant-a", None, 2)], Box::new(FifoPolicy));
        let r = simulate(&trace, &params(4), &solo);
        assert_eq!(r.completed, 0, "no cohort, no borrowing");
    }

    #[test]
    fn unknown_queue_held_and_name_composes() {
        let admitted = QueueAdmission::new(vec![cq("tenant-a", None, 2)], Box::new(FifoPolicy));
        assert_eq!(admitted.name(), "kueue+fifo");
        let trace = Trace::new("t", vec![tenant_job(1, 0.0, 1, 10.0, "ghost-queue")]);
        let r = simulate(&trace, &params(4), &admitted);
        assert_eq!(r.completed, 0, "unknown queue never admits");
    }
}
