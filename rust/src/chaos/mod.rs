//! Deterministic fault-injection harness (PR 10).
//!
//! Chaos here is not random monkey-testing: every scenario is a **named,
//! seed-reproducible schedule of faults** injected at boundaries this
//! codebase owns, driven against the *live* testbed (real daemons, real
//! store, real red-box socket), and judged against the fresh-start fixed
//! point. The paper's testbed claims the orchestration layer hides HPC
//! infrastructure failures from the Kubernetes side; this module is the
//! executable form of that claim.
//!
//! # The model
//!
//! A [`Scenario`] is `(name, seed) -> ChaosReport`. Each scenario:
//!
//! 1. **Computes the golden fixed point** — it runs its workload on a
//!    *clean* testbed and renders an AGE-stripped, `kubectl get`-style
//!    transcript of the converged end state ([`scenarios::transcript`]).
//! 2. **Runs the same workload under faults** — injectors wound into one
//!    owned boundary ([`FaultyApi`] in front of the red-box transport,
//!    [`FaultyWlm`] under the operator, a WAL-backed server kill+restart,
//!    a kubelet killed out from under its pods, a watch-history window
//!    too small for the write load). Every injected fault draws from a
//!    [`FaultPlan`] — a PCG stream seeded from the scenario seed — and is
//!    logged with the trace id of the span held open around it, so
//!    `hpcorc audit` and `kubectl get events` attribute the fallout.
//! 3. **Asserts convergence** — the faulted run must reach a transcript
//!    *byte-identical* to the golden one ([`ChaosReport::converged`]),
//!    plus scenario-specific checks (orphans drained through the
//!    `pods/eviction` subresource, budgets respected, CRDs surviving the
//!    restart, ...).
//!
//! Same seed, same scenario → same fault schedule and the same final
//! transcript (`tests/chaos.rs` runs the matrix twice and diffs).
//!
//! # Running it
//!
//! ```text
//! hpcorc chaos                          # run every scenario, seed 7
//! hpcorc chaos --scenario kubelet-death --seed 42
//! hpcorc chaos --json                   # machine-readable reports
//! ```
//!
//! # Adding a scenario
//!
//! 1. Write `fn my_scenario(seed: u64) -> Result<ChaosReport>` in
//!    [`scenarios`]: boot a golden run, boot a faulted run, drive both to
//!    their fixed points with `transcript()`, record checks.
//! 2. Add it to the [`scenarios()`] registry with a kebab-case name.
//! 3. The CLI, `tests/chaos.rs` matrix, the CI `chaos` job, and
//!    `benches/chaos.rs` all iterate the registry — no further wiring.
//!
//! Fault boundaries are *seams the production code already has*: the
//! [`crate::kube::ApiClient`] trait, the
//! [`crate::hybrid::TestbedConfig::wlm_shim`] hook, the WAL directory,
//! and [`crate::hybrid::Testbed::kill_kubelet`]. Chaos never reaches into
//! private state — if a fault cannot be injected at a public seam, that
//! is a missing seam, not a missing hack.

pub mod fault;
pub mod scenarios;

pub use fault::{Fault, FaultLog, FaultPlan, FaultRecord, FaultyApi, FaultyWlm};

use crate::util::{Error, Result};

/// A named, seed-reproducible fault schedule against the live testbed.
#[derive(Clone, Copy)]
pub struct Scenario {
    pub name: &'static str,
    pub summary: &'static str,
    run: fn(u64) -> Result<ChaosReport>,
}

/// Everything one scenario run produced.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub scenario: String,
    pub seed: u64,
    /// Every injected fault, in injection order, trace-stamped.
    pub faults: Vec<FaultRecord>,
    /// Fixed-point transcript of the clean (golden) run.
    pub golden: String,
    /// Fixed-point transcript of the faulted run.
    pub transcript: String,
    /// Scenario-specific assertions that held (named, human-readable).
    pub checks: Vec<String>,
}

impl ChaosReport {
    /// Did the faulted run converge to the fresh-start fixed point?
    pub fn converged(&self) -> bool {
        self.golden == self.transcript
    }

    /// Human rendering for `hpcorc chaos`.
    pub fn render(&self) -> String {
        let mut out = format!(
            "scenario {} (seed {}): {} — {} faults injected\n",
            self.scenario,
            self.seed,
            if self.converged() { "CONVERGED" } else { "DIVERGED" },
            self.faults.len(),
        );
        for c in &self.checks {
            out.push_str(&format!("  check: {c}\n"));
        }
        for f in self.faults.iter().take(12) {
            out.push_str(&format!(
                "  fault #{:<3} [{}] {:<9} {} trace={}\n",
                f.seq, f.boundary, f.fault, f.op, f.trace
            ));
        }
        if self.faults.len() > 12 {
            out.push_str(&format!("  ... {} more faults\n", self.faults.len() - 12));
        }
        if !self.converged() {
            out.push_str("--- golden ---\n");
            out.push_str(&self.golden);
            out.push_str("--- faulted ---\n");
            out.push_str(&self.transcript);
        }
        out
    }

    /// One-line JSON rendering for `hpcorc chaos --json` / CI artefacts.
    pub fn to_json(&self) -> String {
        let checks: Vec<String> =
            self.checks.iter().map(|c| format!("\"{}\"", c.replace('"', "'"))).collect();
        format!(
            "{{\"scenario\":\"{}\",\"seed\":{},\"converged\":{},\"faults\":{},\"checks\":[{}]}}",
            self.scenario,
            self.seed,
            self.converged(),
            self.faults.len(),
            checks.join(",")
        )
    }
}

/// The scenario registry — the CLI, the test matrix, the CI job, and the
/// bench all iterate this.
pub fn scenarios() -> &'static [Scenario] {
    &[
        Scenario {
            name: "redbox-drop",
            summary: "seeded drop/delay/duplicate faults on the red-box API transport",
            run: scenarios::redbox_drop,
        },
        Scenario {
            name: "apiserver-restart",
            summary: "API server killed mid-admission and restarted over its WAL",
            run: scenarios::apiserver_restart,
        },
        Scenario {
            name: "wlm-slow",
            summary: "slow, lossy WLM backend under the operator",
            run: scenarios::wlm_slow,
        },
        Scenario {
            name: "kubelet-death",
            summary: "kubelet killed under running pods; drain via pods/eviction + PDB",
            run: scenarios::kubelet_death,
        },
        Scenario {
            name: "watch-overflow",
            summary: "watch-history window overflowed by write bursts",
            run: scenarios::watch_overflow,
        },
    ]
}

/// Run one scenario by name. Errors on an unknown name or a failed
/// scenario-internal assertion; a *divergent* transcript is reported via
/// [`ChaosReport::converged`], not an error, so callers can print the diff.
pub fn run_scenario(name: &str, seed: u64) -> Result<ChaosReport> {
    let sc = scenarios()
        .iter()
        .find(|s| s.name == name)
        .ok_or_else(|| {
            let known: Vec<&str> = scenarios().iter().map(|s| s.name).collect();
            Error::internal(format!(
                "unknown chaos scenario `{name}` (known: {})",
                known.join(", ")
            ))
        })?;
    (sc.run)(seed)
}
