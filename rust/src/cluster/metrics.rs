//! Process-wide metrics registry: counters, gauges, latency histograms.
//!
//! Every daemon records into a shared [`Metrics`] handle; the CLI's
//! `hpcorc metrics` and the bench harness read snapshots. Lock granularity
//! is per-metric-map; hot-path increments are atomics.

use crate::util::Hist;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    hists: Mutex<BTreeMap<String, Arc<Mutex<Hist>>>>,
}

/// Cloneable metrics registry handle.
#[derive(Clone, Default)]
pub struct Metrics {
    inner: Arc<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create a counter; returns a cheap handle for hot paths.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.inner.counters.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicU64::new(0))).clone()
    }

    pub fn inc(&self, name: &str) {
        self.counter(name).fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> Arc<AtomicI64> {
        let mut m = self.inner.gauges.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicI64::new(0))).clone()
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    pub fn hist(&self, name: &str) -> Arc<Mutex<Hist>> {
        let mut m = self.inner.hists.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(Mutex::new(Hist::new()))).clone()
    }

    /// Record a duration in nanoseconds into a histogram.
    pub fn observe(&self, name: &str, nanos: u64) {
        self.hist(name).lock().unwrap().record(nanos);
    }

    /// Time a closure into a histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let r = f();
        self.observe(name, t0.elapsed().as_nanos() as u64);
        r
    }

    /// Snapshot all metrics as sorted (name, rendering) lines.
    pub fn snapshot(&self) -> Vec<(String, String)> {
        let mut out = Vec::new();
        for (k, v) in self.inner.counters.lock().unwrap().iter() {
            out.push((k.clone(), v.load(Ordering::Relaxed).to_string()));
        }
        for (k, v) in self.inner.gauges.lock().unwrap().iter() {
            out.push((k.clone(), v.load(Ordering::Relaxed).to_string()));
        }
        for (k, h) in self.inner.hists.lock().unwrap().iter() {
            out.push((k.clone(), h.lock().unwrap().summary(1e6, "ms")));
        }
        out.sort();
        out
    }

    /// Typed counter snapshot, sorted by name.
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Typed gauge snapshot, sorted by name.
    pub fn gauges_snapshot(&self) -> Vec<(String, i64)> {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Histogram snapshot (cloned), sorted by name — what the Prometheus
    /// renderer in `obs::prom` walks for cumulative buckets.
    pub fn hists_snapshot(&self) -> Vec<(String, Hist)> {
        self.inner
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), h.lock().unwrap().clone()))
            .collect()
    }

    /// Read a counter value (0 if absent) — test/bench helper.
    pub fn counter_value(&self, name: &str) -> u64 {
        self.inner
            .counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("jobs.submitted");
        m.add("jobs.submitted", 4);
        assert_eq!(m.counter_value("jobs.submitted"), 5);
        assert_eq!(m.counter_value("missing"), 0);
    }

    #[test]
    fn gauges_set() {
        let m = Metrics::new();
        m.set_gauge("queue.depth", 7);
        m.set_gauge("queue.depth", 3);
        assert_eq!(m.gauge("queue.depth").load(Ordering::Relaxed), 3);
    }

    #[test]
    fn hist_observe_and_time() {
        let m = Metrics::new();
        m.observe("lat", 1_000_000);
        let out = m.time("lat", || 42);
        assert_eq!(out, 42);
        assert_eq!(m.hist("lat").lock().unwrap().count(), 2);
    }

    #[test]
    fn snapshot_sorted() {
        let m = Metrics::new();
        m.inc("b.count");
        m.inc("a.count");
        m.observe("c.lat", 5);
        let snap = m.snapshot();
        let names: Vec<&str> = snap.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, ["a.count", "b.count", "c.lat"]);
    }

    #[test]
    fn handles_shared_across_clones() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.inc("x");
        assert_eq!(m.counter_value("x"), 1);
    }
}
