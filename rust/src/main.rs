//! hpcorc binary entrypoint — see `hpcorc help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(hpcorc::cli::main(argv));
}
