"""Build-time compile path: L2 model + L1 kernels + AOT export.

Never imported at runtime — Rust executes the exported artifacts via PJRT.
"""
