//! Shared scheduling cores — pure, sans-IO policy functions.
//!
//! Both live workload managers (pbs_server, slurmctld), the Kubernetes
//! scheduler approximation used in comparisons, and the discrete-event
//! simulator call into these. Keeping policies pure is what makes the
//! future-work evaluation (paper §V: "compare efficiency of scheduling the
//! container jobs by Kubernetes and Torque") honest: the live path and the
//! large-scale sim run the *same* decision code.

pub mod backfill;
pub mod policy;

pub use backfill::EasyBackfill;
pub use policy::{
    Assignment, FifoPolicy, KubeGreedyPolicy, NodeState, PendingJob, Placement, RunningJob,
    SchedPolicy,
};
