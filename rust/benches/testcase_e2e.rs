//! E8 — end-to-end latency of the paper's test case (Figs. 3-5):
//! TorqueJob submit → dummy pod → qsub → run → results staged → completed.

use hpcorc::bench::{header, Bench};
use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::WlmJobView;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

fn main() {
    println!("=== E8: Fig.3-5 test-case end-to-end latency ===");
    println!("{}", header());
    let tb = Testbed::start(TestbedConfig::default()).expect("boot");
    static SEQ: AtomicU64 = AtomicU64::new(0);

    // Full flow with the echo (lolcow) payload — measures pure orchestration.
    Bench::new("torquejob e2e (echo payload)").warmup(3).iters(40).run(|| {
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let name = format!("bench-{n}");
        let obj = WlmJobView::build_torquejob(
            &name,
            &format!("#PBS -N {name}\n#PBS -o $HOME/{name}.out\nsingularity run lolcow_latest.sif\n"),
            &format!("$HOME/{name}.out"),
            "$HOME/bench/",
        );
        tb.api.create(obj).unwrap();
        let phase = tb.wait_torquejob(&name, Duration::from_secs(30)).unwrap();
        assert_eq!(phase, "completed");
    });

    // Direct qsub of the same script: the WLM-only baseline (the operator
    // overhead is the difference; see operator_overhead for the controlled
    // per-component breakdown).
    Bench::new("direct qsub (same script)").warmup(3).iters(40).run(|| {
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let id = tb
            .pbs
            .qsub(
                &format!("#PBS -N d{n}\n#PBS -o $HOME/d{n}.out\nsingularity run lolcow_latest.sif\n"),
                "bench",
            )
            .unwrap();
        tb.pbs.wait_for(id.seq, Duration::from_secs(30)).unwrap();
    });

    tb.stop();
}
