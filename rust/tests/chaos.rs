//! The deterministic fault-injection matrix (PR 10): every named
//! scenario in the registry must converge to its golden fixed-point
//! transcript, and the seeded ones must reproduce the same transcript
//! when rerun with the same seed. Fault *logs* are not compared across
//! runs — poll-loop iteration counts legitimately vary; the determinism
//! contract is on the converged state.

use hpcorc::chaos::{self, ChaosReport};

fn run(name: &str, seed: u64) -> ChaosReport {
    let report = chaos::run_scenario(name, seed)
        .unwrap_or_else(|e| panic!("chaos scenario {name} (seed {seed}) errored: {e}"));
    assert!(
        report.converged(),
        "chaos scenario {name} (seed {seed}) diverged:\n{}",
        report.render()
    );
    assert!(!report.checks.is_empty(), "{name}: scenario ran no checks");
    report
}

#[test]
fn redbox_drop_converges_and_is_seed_deterministic() {
    let a = run("redbox-drop", 7);
    assert!(!a.faults.is_empty(), "the fault schedule injected nothing");
    assert!(a.faults.iter().all(|f| f.boundary == "api"));
    let b = run("redbox-drop", 7);
    assert_eq!(a.golden, b.golden, "golden transcript changed across same-seed runs");
    assert_eq!(a.transcript, b.transcript, "faulted transcript changed across same-seed runs");
}

#[test]
fn apiserver_restart_recovers_mid_admission_state() {
    let report = run("apiserver-restart", 7);
    assert!(
        report.checks.iter().any(|c| c.contains("CRD short name resolves")),
        "restart scenario must prove CRD registry recovery: {:?}",
        report.checks
    );
}

#[test]
fn wlm_slow_converges_and_is_seed_deterministic() {
    let a = run("wlm-slow", 11);
    assert!(!a.faults.is_empty());
    let b = run("wlm-slow", 11);
    assert_eq!(a.transcript, b.transcript);
}

#[test]
fn kubelet_death_drains_through_eviction() {
    let report = run("kubelet-death", 7);
    assert!(
        report.checks.iter().any(|c| c.contains("PDB vetoed")),
        "kubelet-death must prove budgets bind the chaos drain: {:?}",
        report.checks
    );
    assert!(report.checks.iter().any(|c| c.contains("pods/eviction")));
}

#[test]
fn watch_overflow_forces_the_relist_road() {
    let report = run("watch-overflow", 7);
    assert!(
        report.checks.iter().any(|c| c.contains("410-Gone")),
        "overflow scenario must prove the window actually overflowed: {:?}",
        report.checks
    );
}

#[test]
fn registry_covers_the_advertised_scenarios() {
    let names: Vec<&str> = chaos::scenarios().iter().map(|s| s.name).collect();
    assert_eq!(
        names,
        ["redbox-drop", "apiserver-restart", "wlm-slow", "kubelet-death", "watch-overflow"],
        "scenario registry drifted from the documented set"
    );
    for sc in chaos::scenarios() {
        assert!(!sc.summary.is_empty(), "{}: empty summary", sc.name);
    }
    let err = chaos::run_scenario("bogus", 1).unwrap_err().to_string();
    assert!(err.contains("redbox-drop"), "unknown-scenario error lists the known names: {err}");
}
