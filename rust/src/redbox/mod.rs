//! red-box: the Unix-socket RPC bridge between the Kubernetes side and the
//! Torque side of the login node (paper §II/§III-B).
//!
//! WLM-Operator implements red-box as a gRPC proxy; this is the same
//! three-piece shape — a service definition ([`proto`]), a server that
//! listens and dispatches ([`server`]), and clients that mirror the methods
//! ([`client`]) — over length-prefixed JSON frames on a real Unix domain
//! socket.

pub mod client;
pub mod proto;
pub mod server;

pub use client::RedboxClient;
pub use proto::{Request, Response};
pub use server::{FnService, RedboxServer, Service};
