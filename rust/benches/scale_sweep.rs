//! E3 — scalability: jobs × nodes sweep on the simulator, reporting both
//! the scheduling outcomes and the simulator's own throughput (events/s),
//! plus live-testbed job throughput at increasing concurrency.

use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::WlmJobView;
use hpcorc::sched::EasyBackfill;
use hpcorc::sim::{simulate, SimParams};
use hpcorc::workload::TraceGen;
use std::time::{Duration, Instant};

fn main() {
    println!("=== E3: scale sweep ===\n");
    println!("--- sim: jobs x nodes (easy-backfill) ---");
    println!(
        "{:<8} {:<8} {:>12} {:>12} {:>12} {:>14}",
        "jobs", "nodes", "makespan", "mean wait", "util", "sim wallclock"
    );
    for &jobs in &[256usize, 1024, 4096] {
        for &nodes in &[16usize, 64, 256] {
            let cores = 8u32;
            let trace = TraceGen::new(7).poisson_batch(
                jobs,
                nodes as u32 * cores,
                0.9,
                180.0,
            );
            let params = SimParams { nodes, cores_per_node: cores, ..SimParams::default() };
            let t0 = Instant::now();
            let r = simulate(&trace, &params, &EasyBackfill);
            let wall = t0.elapsed();
            println!(
                "{:<8} {:<8} {:>11.0}s {:>11.1}s {:>11.1}% {:>13.1}ms",
                jobs,
                nodes,
                r.makespan_s,
                r.mean_wait_s,
                r.utilization * 100.0,
                wall.as_secs_f64() * 1e3
            );
        }
    }

    println!("\n--- live testbed: concurrent TorqueJobs -> throughput ---");
    println!("{:>6} {:>12} {:>12}", "jobs", "wall", "jobs/s");
    for &n in &[8usize, 32, 64] {
        let mut cfg = TestbedConfig::default();
        cfg.torque_nodes = 8;
        let tb = Testbed::start(cfg).expect("boot");
        let t0 = Instant::now();
        for i in 0..n {
            let name = format!("s{i}");
            tb.api
                .create(WlmJobView::build_torquejob(
                    &name,
                    &format!("#PBS -N {name}\necho x\n"),
                    "",
                    "",
                ))
                .unwrap();
        }
        for i in 0..n {
            tb.wait_torquejob(&format!("s{i}"), Duration::from_secs(120)).unwrap();
        }
        let wall = t0.elapsed();
        println!(
            "{:>6} {:>11.2}s {:>12.1}",
            n,
            wall.as_secs_f64(),
            n as f64 / wall.as_secs_f64()
        );
        tb.stop();
    }
}
