//! Integration tests over the public API: the full testbed, the remote
//! (socket) surface, both operators, and failure paths — everything a
//! downstream user touches.

use hpcorc::encoding::Value;
use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::{
    ApiClient, ListOptions, RemoteApi, WlmJobView, KIND_POD, KIND_SLURMJOB, KIND_TORQUEJOB,
};
use hpcorc::redbox::RedboxClient;
use std::time::Duration;

#[test]
fn cow_job_via_remote_api_over_socket() {
    // The CLI path: kubectl apply over the red-box socket, not in-proc.
    let tb = Testbed::start(TestbedConfig::default()).unwrap();
    let api = RemoteApi::connect(tb.socket()).unwrap();
    let objs = hpcorc::kube::yaml::parse_manifest(hpcorc::kube::yaml::COW_JOB_YAML).unwrap();
    api.apply(objs[0].clone()).unwrap();
    let phase = tb.wait_torquejob("cow", Duration::from_secs(30)).unwrap();
    assert_eq!(phase, "completed");
    // kubectl get torquejob over the socket shows the Fig. 4 row.
    let list = api.list(KIND_TORQUEJOB, &ListOptions::all()).unwrap();
    assert_eq!(list.items.len(), 1);
    assert_eq!(list.items[0].status.opt_str("phase"), Some("completed"));
    // qstat over the socket agrees.
    let job_id = list.items[0].status.opt_str("jobId").unwrap().to_string();
    let client = RedboxClient::connect(tb.socket()).unwrap();
    let st = client
        .call("torque.Workload/JobStatus", Value::map().with("jobId", job_id))
        .unwrap();
    assert_eq!(st.opt_str("state"), Some("completed"));
    tb.stop();
}

#[test]
fn torque_and_slurm_operators_same_workload() {
    // E4: the same lolcow workload through both operators.
    let mut cfg = TestbedConfig::default();
    cfg.with_slurm = true;
    let tb = Testbed::start(cfg).unwrap();

    tb.api
        .create(WlmJobView::build_torquejob(
            "via-torque",
            "#PBS -o $HOME/t.out\nsingularity run lolcow_latest.sif\n",
            "$HOME/t.out",
            "$HOME/res-t/",
        ))
        .unwrap();
    let mut sjob = WlmJobView::build_torquejob(
        "via-slurm",
        "#SBATCH -o $HOME/s.out\nsingularity run lolcow_latest.sif\n",
        "$HOME/s.out",
        "$HOME/res-s/",
    );
    sjob.kind = KIND_SLURMJOB.into();
    tb.api.create(sjob).unwrap();

    assert_eq!(tb.wait_torquejob("via-torque", Duration::from_secs(30)).unwrap(), "completed");
    assert_eq!(tb.wait_slurmjob("via-slurm", Duration::from_secs(30)).unwrap(), "completed");
    assert!(tb.fs.read_string("$HOME/res-t/t.out").unwrap().contains("Moo"));
    assert!(tb.fs.read_string("$HOME/res-s/s.out").unwrap().contains("Moo"));
    tb.stop();
}

#[test]
fn many_concurrent_torquejobs() {
    let mut cfg = TestbedConfig::default();
    cfg.torque_nodes = 4;
    let tb = Testbed::start(cfg).unwrap();
    let n = 20;
    for i in 0..n {
        let name = format!("batch-{i:02}");
        tb.api
            .create(WlmJobView::build_torquejob(
                &name,
                &format!("#PBS -N {name}\n#PBS -o $HOME/{name}.out\necho job {i} done\nsleep 5\n"),
                &format!("$HOME/{name}.out"),
                "$HOME/out/",
            ))
            .unwrap();
    }
    for i in 0..n {
        let name = format!("batch-{i:02}");
        let phase = tb.wait_torquejob(&name, Duration::from_secs(60)).unwrap();
        assert_eq!(phase, "completed", "{name}");
        assert_eq!(
            tb.fs.read_string(&format!("$HOME/out/{name}.out")).unwrap(),
            format!("job {i} done\n")
        );
    }
    // Every job produced exactly one submit + one collect pod.
    let pods = tb.api.list(KIND_POD, &[]);
    assert_eq!(
        pods.iter().filter(|p| p.meta.name.ends_with("-submit")).count(),
        n
    );
    assert_eq!(
        pods.iter().filter(|p| p.meta.name.ends_with("-collect")).count(),
        n
    );
    tb.stop();
}

#[test]
fn queue_routing_respects_pbs_q_directive() {
    let mut cfg = TestbedConfig::default();
    cfg.extra_queues = vec![("express".into(), 100)];
    let tb = Testbed::start(cfg).unwrap();
    tb.api
        .create(WlmJobView::build_torquejob(
            "fast",
            "#PBS -q express\n#PBS -o $HOME/f.out\necho express\n",
            "$HOME/f.out",
            "$HOME/",
        ))
        .unwrap();
    assert_eq!(tb.wait_torquejob("fast", Duration::from_secs(30)).unwrap(), "completed");
    // Dummy pod must have landed on the express virtual node.
    let dummy = tb.api.get(KIND_POD, "fast-submit").unwrap();
    assert_eq!(dummy.spec.opt_str("nodeName"), Some("vnode-torque-express"));
    tb.stop();
}

#[test]
fn plain_pods_and_torquejobs_coexist() {
    // Paper's claim: "flexibility to run containerised and
    // non-containerised jobs" — normal pods on kube workers while
    // TorqueJobs flow to the HPC side.
    let tb = Testbed::start(TestbedConfig::default()).unwrap();
    let pod = hpcorc::kube::PodView::build(
        "web",
        "lolcow_latest.sif",
        hpcorc::cluster::Resources::new(100, 1 << 20, 0),
        &[],
    );
    tb.api.create(pod).unwrap();
    tb.api
        .create(WlmJobView::build_torquejob(
            "hpc",
            "#PBS -o $HOME/h.out\necho hpc\n",
            "$HOME/h.out",
            "$HOME/",
        ))
        .unwrap();
    let pod = tb.wait_pod("web", Duration::from_secs(30)).unwrap();
    assert_eq!(pod.status.opt_str("phase"), Some("Succeeded"));
    let node = pod.spec.opt_str("nodeName").unwrap();
    assert!(!node.starts_with("vnode-"), "plain pod on a real worker, got {node}");
    assert_eq!(tb.wait_torquejob("hpc", Duration::from_secs(30)).unwrap(), "completed");
    tb.stop();
}

#[test]
fn direct_qsub_bypasses_kubernetes() {
    // Non-containerised path: qsub straight at pbs_server.
    let tb = Testbed::start(TestbedConfig::default()).unwrap();
    let id = tb.pbs.qsub("#PBS -o $HOME/direct.out\necho direct\n", "user").unwrap();
    let job = tb.pbs.wait_for(id.seq, Duration::from_secs(30)).unwrap();
    assert_eq!(job.exit_code, Some(0));
    assert_eq!(tb.fs.read_string("$HOME/direct.out").unwrap(), "direct\n");
    assert!(tb.api.list(KIND_POD, &[]).is_empty(), "no kube involvement");
    tb.stop();
}
