//! `hpcorc` command-line interface (clap substitute).
//!
//! Two families of verbs, matching the paper's two user surfaces:
//! kubectl-style (`apply`, `get`, `delete`, `logs`) against a running
//! testbed's red-box socket, and Torque-style (`qsub`, `qstat`, `qdel`)
//! against the same socket's `torque.Workload` service. Plus testbed
//! lifecycle (`up`, `demo`), workload tooling (`trace`, `sim`) and
//! `version --components` (paper Table I).

pub mod args;
pub mod commands;

pub use args::Args;

/// CLI entrypoint; returns the process exit code.
pub fn main(argv: Vec<String>) -> i32 {
    crate::util::log::init_from_env();
    let mut args = Args::new(argv);
    let verb = match args.positional(0) {
        Some(v) => v.to_string(),
        None => {
            eprint!("{}", commands::USAGE);
            return 2;
        }
    };
    let result = match verb.as_str() {
        "up" => commands::cmd_up(&mut args),
        "demo" => commands::cmd_demo(&mut args),
        "kubectl" => commands::cmd_kubectl(&mut args),
        "qsub" => commands::cmd_qsub(&mut args),
        "qstat" => commands::cmd_qstat(&mut args),
        "qdel" => commands::cmd_qdel(&mut args),
        "trace" => commands::cmd_trace(&mut args),
        "metrics" => commands::cmd_metrics(&mut args),
        "audit" => commands::cmd_audit(&mut args),
        "chaos" => commands::cmd_chaos(&mut args),
        "sim" => commands::cmd_sim(&mut args),
        "sing" => commands::cmd_sing(&mut args),
        "version" => commands::cmd_version(&mut args),
        "help" | "--help" | "-h" => {
            print!("{}", commands::USAGE);
            Ok(())
        }
        other => {
            eprintln!("hpcorc: unknown command `{other}`\n");
            eprint!("{}", commands::USAGE);
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("hpcorc {verb}: {e}");
            1
        }
    }
}
