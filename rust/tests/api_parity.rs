//! Local-vs-remote parity: the same CRUD/list/patch/watch scenario runs
//! through the in-process `ApiServer` and through `RemoteApi` over a
//! red-box socket — in both its streaming and poll-fallback watch modes —
//! and must produce an identical transcript. This is the contract that
//! lets controllers hold `Arc<dyn ApiClient>` without caring which side
//! of the socket (or which watch transport) they run on.

use hpcorc::cluster::{Metrics, Resources};
use hpcorc::encoding::Value;
use hpcorc::kube::{
    scheduling_gates, ApiClient, ApiServer, CrdView, EvictionMode, KubeObject, ListOptions,
    NodeView, PdbView, PodView, RemoteApi, WatchConfig, WatchEvent, WatchMode,
    KIND_CUSTOMRESOURCEDEFINITION, KIND_NODE, KIND_POD, KIND_PODDISRUPTIONBUDGET,
};
use hpcorc::redbox::RedboxServer;
use hpcorc::rt::Shutdown;
use std::time::{Duration, Instant};

fn pod(name: &str) -> hpcorc::kube::KubeObject {
    PodView::build(name, "img.sif", Resources::new(250, 1 << 20, 0), &[])
}

/// Drain `n` watch events, tolerating the remote transport's poll latency.
fn collect_events(rx: &std::sync::mpsc::Receiver<WatchEvent>, n: usize) -> Vec<String> {
    let mut events = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(5);
    while events.len() < n && Instant::now() < deadline {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(ev) => events.push(format!(
                "{} {}/{} rv={}",
                ev.type_str(),
                ev.object().kind,
                ev.object().meta.name,
                ev.object().meta.resource_version
            )),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
            Err(_) => break,
        }
    }
    events
}

/// The scenario: every verb of the unified API, recorded as a transcript
/// of transport-independent observations (uids, resourceVersions, error
/// types, watch events — never wall-clock).
fn scenario(api: &dyn ApiClient) -> Vec<String> {
    let mut t: Vec<String> = Vec::new();

    // Watch the Pod kind from the beginning: replay + live both covered.
    let rx = api.watch(Some(KIND_POD), 0).expect("watch");

    // -- create --------------------------------------------------------
    let mut p1 = pod("p1");
    p1.meta.set_label("app", "web");
    let created = api.create(p1).expect("create p1");
    t.push(format!("create p1 uid={} rv={}", created.meta.uid, created.meta.resource_version));
    let dup = api.create(pod("p1")).unwrap_err();
    t.push(format!(
        "dup already_exists={} not_found={}",
        matches!(dup, hpcorc::util::Error::Api(hpcorc::util::ApiError::AlreadyExists { .. })),
        dup.is_not_found()
    ));
    let mut p2 = pod("p2");
    p2.spec.insert("nodeName", "w2");
    let created2 = api.create(p2).expect("create p2");
    t.push(format!("create p2 uid={} rv={}", created2.meta.uid, created2.meta.resource_version));
    // A Node too: proves kind-filtered list/watch ignore it.
    api.create(NodeView::build("n1", Resources::cores(8, 32 << 30), &[])).expect("node");

    // -- get / update_status / patch -----------------------------------
    let missing = api.get(KIND_POD, "ghost").unwrap_err();
    t.push(format!("get ghost not_found={}", missing.is_not_found()));
    let o = api
        .update_status(KIND_POD, "p1", &|o| {
            o.status.insert("phase", "Running");
        })
        .expect("update_status");
    t.push(format!("us p1 rv={} phase={}", o.meta.resource_version, o.status.opt_str("phase").unwrap_or("")));
    let o = api
        .patch_merge(
            KIND_POD,
            "p1",
            &Value::map()
                .with("status", Value::map().with("exitCode", 0i64))
                .with(
                    "metadata",
                    Value::map().with("labels", Value::map().with("tier", "frontend")),
                ),
        )
        .expect("patch");
    t.push(format!(
        "patch p1 rv={} exit={} tier={}",
        o.meta.resource_version,
        o.status.opt_int("exitCode").unwrap_or(-1),
        o.meta.label("tier").unwrap_or("")
    ));

    // -- list: label selector, field selector, freshness ----------------
    let by_label = api
        .list(KIND_POD, &ListOptions::all().with_label("app", "web"))
        .expect("list by label");
    t.push(format!(
        "list app=web rv={} items={:?}",
        by_label.resource_version,
        by_label.items.iter().map(|o| o.meta.name.clone()).collect::<Vec<_>>()
    ));
    let by_field = api
        .list(KIND_POD, &ListOptions::all().with_field("spec.nodeName", "w2"))
        .expect("list by field");
    t.push(format!(
        "list nodeName=w2 items={:?}",
        by_field.items.iter().map(|o| o.meta.name.clone()).collect::<Vec<_>>()
    ));
    let nodes = api.list(KIND_NODE, &ListOptions::all()).expect("list nodes");
    t.push(format!("list nodes n={}", nodes.items.len()));
    let too_new = api
        .list(KIND_POD, &ListOptions::all().not_older_than(by_field.resource_version + 100))
        .unwrap_err();
    t.push(format!("list too-new conflict={}", too_new.is_conflict()));

    // -- delete with owner cascade --------------------------------------
    let mut child = pod("p1-child");
    child.meta.owner = Some((KIND_POD.to_string(), "p1".to_string()));
    api.create(child).expect("child");
    api.delete(KIND_POD, "p1").expect("delete p1");
    t.push(format!(
        "cascade child_gone={} root_gone={}",
        api.get(KIND_POD, "p1-child").unwrap_err().is_not_found(),
        api.get(KIND_POD, "p1").unwrap_err().is_not_found()
    ));

    // -- watch transcript -----------------------------------------------
    // create p1, create p2, us p1, patch p1, create child, del child, del p1.
    t.extend(collect_events(&rx, 7));
    t
}

#[test]
fn same_scenario_identical_through_both_transports() {
    // Local: straight at a fresh in-process server.
    let local_api = ApiServer::new(Metrics::new());
    let local = scenario(&local_api);

    // Remote: a fresh server behind a red-box socket.
    let sd = Shutdown::new();
    let path = std::env::temp_dir()
        .join(format!("hpcorc-parity-{}.sock", std::process::id()));
    let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
    let remote_server = ApiServer::new(Metrics::new());
    srv.register("kube.Api", remote_server.rpc_service());
    let remote_api = RemoteApi::connect(&path).unwrap();
    let remote = scenario(&remote_api);
    srv.stop();

    assert_eq!(
        local, remote,
        "local and remote ApiClient transcripts diverged"
    );
    // Sanity: the transcript actually covered the verbs (not all empty).
    assert_eq!(local.len(), 11 + 7, "scenario shape changed — update the count");
    assert!(local.iter().any(|l| l.starts_with("ADDED Pod/p1 ")));
    assert!(local.iter().any(|l| l.starts_with("DELETED Pod/p1-child ")));
}

/// Paged lists (`limit`/`continue`, ROADMAP follow-up) must page
/// identically through both transports: same page shapes, same cursors,
/// same items, and selectors compose with paging.
#[test]
fn paged_lists_identical_through_both_transports() {
    fn paging_scenario(api: &dyn ApiClient) -> Vec<String> {
        let mut t = Vec::new();
        for i in 0..7 {
            let mut p = pod(&format!("pg{i}"));
            if i % 2 == 0 {
                p.meta.set_label("parity", "even");
            }
            api.create(p).expect("create");
        }
        let mut opts = ListOptions::all().with_limit(3);
        loop {
            let page = api.list(KIND_POD, &opts).expect("page");
            t.push(format!(
                "page items={:?} cont={:?}",
                page.items.iter().map(|o| o.meta.name.clone()).collect::<Vec<_>>(),
                page.continue_token
            ));
            match page.continue_token {
                Some(tok) => opts = ListOptions::all().with_limit(3).continue_from(&tok),
                None => break,
            }
        }
        // Selectors compose with paging.
        let page = api
            .list(KIND_POD, &ListOptions::all().with_label("parity", "even").with_limit(2))
            .expect("filtered page");
        t.push(format!(
            "filtered items={:?} cont={:?}",
            page.items.iter().map(|o| o.meta.name.clone()).collect::<Vec<_>>(),
            page.continue_token
        ));
        t
    }

    let local_api = ApiServer::new(Metrics::new());
    let local = paging_scenario(&local_api);

    let sd = Shutdown::new();
    let path = std::env::temp_dir()
        .join(format!("hpcorc-parity-paged-{}.sock", std::process::id()));
    let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
    let remote_server = ApiServer::new(Metrics::new());
    srv.register("kube.Api", remote_server.rpc_service());
    let remote_api = RemoteApi::connect(&path).unwrap();
    let remote = paging_scenario(&remote_api);
    srv.stop();

    assert_eq!(local, remote, "paged list transcripts diverged");
    assert_eq!(local.len(), 3 + 1, "3 pages of 7 at limit 3, plus the filtered page");
    assert!(local[0].contains("pg0") && local[0].contains("cont=Some"));
    assert!(local[2].contains("cont=None"));
    assert!(local[3].contains("pg0") && local[3].contains("pg2") && local[3].contains("cont=Some"));
}

// ---------------------------------------------------------------------
// Watch-transcript parity (ISSUE 5): the full watch lifecycle — live
// events, mid-stream server loss, bookmark replay after recovery, and
// the 410-Gone stale-bookmark path — must read identically through the
// in-process server, the poll-based remote, and the streaming remote.
// ---------------------------------------------------------------------

/// In-process `ApiClient` whose watch streams can be severed on demand —
/// the in-process equivalent of a server restart, so all three
/// transports run the *same* disruption scenario.
struct KillableApi {
    api: ApiServer,
    taps: std::sync::Mutex<Vec<Shutdown>>,
}

impl KillableApi {
    fn new(api: ApiServer) -> KillableApi {
        KillableApi { api, taps: std::sync::Mutex::new(Vec::new()) }
    }

    fn kill_streams(&self) {
        for sd in self.taps.lock().unwrap().drain(..) {
            sd.trigger();
        }
    }
}

impl ApiClient for KillableApi {
    fn create(&self, obj: hpcorc::kube::KubeObject) -> hpcorc::util::Result<hpcorc::kube::KubeObject> {
        self.api.create(obj)
    }
    fn get(&self, kind: &str, name: &str) -> hpcorc::util::Result<hpcorc::kube::KubeObject> {
        self.api.get(kind, name)
    }
    fn update(&self, obj: hpcorc::kube::KubeObject) -> hpcorc::util::Result<hpcorc::kube::KubeObject> {
        ApiServer::update(&self.api, obj)
    }
    fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: &dyn Fn(&mut hpcorc::kube::KubeObject),
    ) -> hpcorc::util::Result<hpcorc::kube::KubeObject> {
        self.api.update_status(kind, name, f)
    }
    fn patch_merge(
        &self,
        kind: &str,
        name: &str,
        patch: &Value,
    ) -> hpcorc::util::Result<hpcorc::kube::KubeObject> {
        self.api.patch_merge(kind, name, patch)
    }
    fn delete(&self, kind: &str, name: &str) -> hpcorc::util::Result<hpcorc::kube::KubeObject> {
        self.api.delete(kind, name)
    }
    fn apply(&self, obj: hpcorc::kube::KubeObject) -> hpcorc::util::Result<hpcorc::kube::KubeObject> {
        self.api.apply(obj)
    }
    fn list(
        &self,
        kind: &str,
        opts: &ListOptions,
    ) -> hpcorc::util::Result<hpcorc::kube::ObjectList> {
        self.api.list_opts(kind, opts)
    }
    fn watch(
        &self,
        kind: Option<&str>,
        from: u64,
    ) -> hpcorc::util::Result<std::sync::mpsc::Receiver<WatchEvent>> {
        let upstream = ApiServer::watch(&self.api, kind, from);
        let (tx, rx) = std::sync::mpsc::channel();
        let sd = Shutdown::new();
        self.taps.lock().unwrap().push(sd.clone());
        hpcorc::rt::spawn_named("parity-killable-watch", move || loop {
            if sd.is_triggered() {
                return; // drops tx: stream severed
            }
            match upstream.recv_timeout(Duration::from_millis(1)) {
                Ok(ev) => {
                    if tx.send(ev).is_err() {
                        return;
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(_) => return,
            }
        });
        Ok(rx)
    }
    fn server_time_s(&self) -> hpcorc::util::Result<f64> {
        Ok(self.api.now_s())
    }
}

/// Block until the watch stream ends (sender side dropped); `true` when
/// it did within the deadline.
fn wait_stream_end(rx: &std::sync::mpsc::Receiver<WatchEvent>) -> bool {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(_) => {} // late events racing the disruption are fine
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return true,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if Instant::now() > deadline {
                    return false;
                }
            }
        }
    }
}

/// The watch lifecycle, recorded transport-independently. `server` is
/// the authoritative state (the same write sequence runs for every
/// transport); `client` is the transport under test; `disrupt`/`restore`
/// sever and re-establish the transport's event path.
fn watch_scenario(
    server: &ApiServer,
    client: &dyn ApiClient,
    disrupt: &mut dyn FnMut(),
    restore: &mut dyn FnMut(),
) -> Vec<String> {
    let mut t = Vec::new();

    // -- live events (foreign kinds filtered) ---------------------------
    let rx = client.watch(Some(KIND_POD), 0).expect("watch");
    server.create(pod("w1")).expect("w1");
    server
        .update_status(KIND_POD, "w1", |o| {
            o.status.insert("phase", "Running");
        })
        .expect("us w1");
    server
        .create(NodeView::build("n1", Resources::cores(8, 32 << 30), &[]))
        .expect("node");
    t.extend(collect_events(&rx, 2));

    // -- mid-stream server loss -----------------------------------------
    let bookmark = server.current_version();
    disrupt();
    t.push(format!("stream lost={}", wait_stream_end(&rx)));

    // The blind window: the world changes while the transport is down.
    server.create(pod("w2")).expect("w2");
    server.delete(KIND_POD, "w1").expect("del w1");
    restore();

    // -- recovery: rewatch from the pre-loss bookmark replays the blind
    // window (it is still inside the retained history) ------------------
    let rx = client.watch(Some(KIND_POD), bookmark).expect("rewatch");
    t.extend(collect_events(&rx, 2));

    // -- and the recovered stream is live again -------------------------
    server.create(pod("w3")).expect("w3");
    t.extend(collect_events(&rx, 1));
    t
}

/// The 410 path: a bookmark that fell out of the retained history window
/// must yield an immediately-ended, zero-event stream; a fresh bookmark
/// on the same server still watches live.
fn gone_scenario(server: &ApiServer, client: &dyn ApiClient) -> Vec<String> {
    let mut t = Vec::new();
    let stale = server.create(pod("seed")).expect("seed").meta.resource_version;
    for i in 0..100u64 {
        server
            .update_status(KIND_POD, "seed", |o| {
                o.status.insert("n", i);
            })
            .expect("burst");
    }
    let rx = client.watch(Some(KIND_POD), stale).expect("stale watch");
    let mut events = 0;
    let deadline = Instant::now() + Duration::from_secs(5);
    let ended = loop {
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(_) => events += 1,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break true,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if Instant::now() > deadline {
                    break false;
                }
            }
        }
    };
    t.push(format!("stale watch events={events} ended={ended}"));
    let rx = client.watch(Some(KIND_POD), server.current_version()).expect("fresh watch");
    server.create(pod("after")).expect("after");
    t.extend(collect_events(&rx, 1));
    t
}

fn parity_sock(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("hpcorc-parity-{tag}-{}.sock", std::process::id()))
}

#[test]
fn watch_transcript_identical_across_all_three_transports() {
    let mut transcripts: Vec<(&str, Vec<String>)> = Vec::new();

    // -- transport 1: in-process (severable wrapper) --------------------
    let local_server = ApiServer::new(Metrics::new());
    let killable = std::sync::Arc::new(KillableApi::new(local_server.clone()));
    {
        let k = killable.clone();
        let mut disrupt = move || k.kill_streams();
        let mut restore = || {};
        let t = watch_scenario(
            &local_server,
            killable.as_ref() as &dyn ApiClient,
            &mut disrupt,
            &mut restore,
        );
        eprintln!("in-process: watch mode = local");
        transcripts.push(("in-process", t));
    }

    // -- transports 2+3: remote over red-box, poll and streaming --------
    for (label, force_poll, want_mode) in [
        ("poll-remote", true, WatchMode::Poll),
        ("streaming-remote", false, WatchMode::Streaming),
    ] {
        let server = ApiServer::new(Metrics::new());
        let path = parity_sock(label);
        let first = RedboxServer::start(&path, Shutdown::new(), Metrics::new()).unwrap();
        first.register("kube.Api", server.rpc_service());
        let srv_cell = std::cell::RefCell::new(Some(first));
        let remote = RemoteApi::connect(&path)
            .unwrap()
            .with_watch_config(WatchConfig { force_poll, ..WatchConfig::default() });

        let t = {
            let mut disrupt = || {
                // Server down — and it *stays* down through the blind
                // window, so even the reconnecting poll loop ends.
                if let Some(mut s) = srv_cell.borrow_mut().take() {
                    s.stop();
                }
            };
            let mut restore = || {
                // Same socket, same ApiServer state: a server restart.
                let s = RedboxServer::start(&path, Shutdown::new(), Metrics::new()).unwrap();
                s.register("kube.Api", server.rpc_service());
                *srv_cell.borrow_mut() = Some(s);
            };
            watch_scenario(&server, &remote, &mut disrupt, &mut restore)
        };
        // ISSUE 5 satellite: the transport reports its watch mode.
        eprintln!("{label}: watch mode = {:?}", remote.last_watch_mode());
        assert_eq!(remote.last_watch_mode(), Some(want_mode), "{label} negotiated wrong mode");
        transcripts.push((label, t));
        if let Some(mut s) = srv_cell.borrow_mut().take() {
            s.stop();
        }
    }

    let (_, reference) = &transcripts[0];
    for (label, t) in &transcripts[1..] {
        assert_eq!(t, reference, "{label} watch transcript diverged from in-process");
    }
    // Shape sanity: the transcript really covered the lifecycle.
    assert_eq!(reference.len(), 2 + 1 + 2 + 1, "scenario shape changed — update the count");
    assert!(reference.iter().any(|l| l == "stream lost=true"));
    assert!(reference.iter().any(|l| l.starts_with("DELETED Pod/w1 ")));
    assert!(reference.iter().any(|l| l.starts_with("ADDED Pod/w3 ")));
}

#[test]
fn gone_reset_identical_across_all_three_transports() {
    const HISTORY: usize = 64; // small window: the burst trims the seed

    let mut transcripts: Vec<(&str, Vec<String>)> = Vec::new();

    let local_server = ApiServer::with_history_cap(Metrics::new(), HISTORY);
    let killable = KillableApi::new(local_server.clone());
    transcripts.push(("in-process", gone_scenario(&local_server, &killable)));

    for (label, force_poll, want_mode) in [
        ("poll-remote", true, WatchMode::Poll),
        ("streaming-remote", false, WatchMode::Streaming),
    ] {
        let server = ApiServer::with_history_cap(Metrics::new(), HISTORY);
        let path = parity_sock(&format!("gone-{label}"));
        let mut srv = RedboxServer::start(&path, Shutdown::new(), Metrics::new()).unwrap();
        srv.register("kube.Api", server.rpc_service());
        let remote = RemoteApi::connect(&path)
            .unwrap()
            .with_watch_config(WatchConfig { force_poll, ..WatchConfig::default() });
        transcripts.push((label, gone_scenario(&server, &remote)));
        eprintln!("{label}: watch mode = {:?}", remote.last_watch_mode());
        assert_eq!(remote.last_watch_mode(), Some(want_mode));
        srv.stop();
    }

    let (_, reference) = &transcripts[0];
    for (label, t) in &transcripts[1..] {
        assert_eq!(t, reference, "{label} 410 transcript diverged from in-process");
    }
    assert_eq!(reference[0], "stale watch events=0 ended=true");
    assert!(reference[1].starts_with("ADDED Pod/after "));
}

#[test]
fn typed_api_handles_identical_through_both_transports() {
    use hpcorc::kube::Api;
    fn typed_scenario(client: std::sync::Arc<dyn ApiClient>) -> Vec<String> {
        let pods: Api<PodView> = Api::new(client);
        let v = pods.create(pod("tp")).expect("typed create");
        let mut t = vec![format!("created {} image={} phase={:?}", v.name, v.image, v.phase)];
        let v = pods
            .update_status("tp", &|o| {
                o.status.insert("phase", "Running");
            })
            .expect("typed us");
        t.push(format!("running {:?}", v.phase));
        let listed = pods.list(&ListOptions::all()).expect("typed list");
        t.push(format!("listed {:?}", listed.iter().map(|p| p.name.clone()).collect::<Vec<_>>()));
        pods.delete("tp").expect("typed delete");
        t.push(format!("gone {}", pods.get("tp").unwrap_err().is_not_found()));
        t
    }

    let local_api = ApiServer::new(Metrics::new());
    let local = typed_scenario(local_api.client());

    let sd = Shutdown::new();
    let path = std::env::temp_dir()
        .join(format!("hpcorc-parity-typed-{}.sock", std::process::id()));
    let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
    let remote_server = ApiServer::new(Metrics::new());
    srv.register("kube.Api", remote_server.rpc_service());
    let remote_api = RemoteApi::connect(&path).unwrap();
    let remote = typed_scenario(std::sync::Arc::new(remote_api));
    srv.stop();

    assert_eq!(local, remote);
}

/// The per-shard version contract (PR 6), in transcript terms: every
/// kind lives in its own store shard (own lock, history, last-version),
/// but resource versions are drawn from ONE global counter, and any
/// list's `resourceVersion` — full or delta, any kind — reports that
/// global version. Foreign-kind churn therefore advances the version a
/// node client observes (PR 5's cross-kind BOOKMARK semantics) while a
/// node *delta* list ships zero items for it. Identical through both
/// transports.
#[test]
fn sharded_delta_lists_identical_through_both_transports() {
    fn delta_scenario(api: &dyn ApiClient) -> Vec<String> {
        let mut t = Vec::new();
        for i in 0..3 {
            api.create(pod(&format!("d{i}"))).expect("create");
        }
        api.create(NodeView::build("dn1", Resources::cores(8, 32 << 30), &[]))
            .expect("node");
        let floor = api.list(KIND_POD, &ListOptions::all()).expect("floor").resource_version;

        // Pod-shard churn only; the node shard sees none of it.
        api.update_status(KIND_POD, "d1", &|o| {
            o.status.insert("phase", "Running");
        })
        .expect("us");
        api.delete(KIND_POD, "d2").expect("del");
        api.create(pod("d3")).expect("late create");

        let pods = api
            .list(KIND_POD, &ListOptions::all().delta_since(floor))
            .expect("pod delta");
        t.push(format!(
            "pod delta={} items={:?} deleted={:?}",
            pods.delta,
            pods.items.iter().map(|o| o.meta.name.clone()).collect::<Vec<_>>(),
            pods.deleted
        ));
        let nodes = api
            .list(KIND_NODE, &ListOptions::all().delta_since(floor))
            .expect("node delta");
        t.push(format!(
            "node delta={} items={} deleted={} (foreign churn ships nothing)",
            nodes.delta,
            nodes.items.len(),
            nodes.deleted.len()
        ));
        // One global version counter across all shards: a full node list
        // observes the version the pod churn advanced it to.
        let full_nodes = api.list(KIND_NODE, &ListOptions::all()).expect("full nodes");
        t.push(format!(
            "global version: node full rv == pod delta rv = {}",
            full_nodes.resource_version == pods.resource_version
        ));
        t
    }

    let local_api = ApiServer::new(Metrics::new());
    let local = delta_scenario(&local_api);

    let sd = Shutdown::new();
    let path = std::env::temp_dir()
        .join(format!("hpcorc-parity-delta-{}.sock", std::process::id()));
    let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
    let remote_server = ApiServer::new(Metrics::new());
    srv.register("kube.Api", remote_server.rpc_service());
    let remote_api = RemoteApi::connect(&path).unwrap();
    let remote = delta_scenario(&remote_api);
    srv.stop();

    assert_eq!(local, remote, "sharded delta-list transcripts diverged");
    assert_eq!(
        local[0],
        r#"pod delta=true items=["d1", "d3"] deleted=["d2"]"#,
        "delta coalesces per name: final states + deleted names only"
    );
    assert_eq!(local[1], "node delta=true items=0 deleted=0 (foreign churn ships nothing)");
    assert_eq!(local[2], "global version: node full rv == pod delta rv = true");
}

// ---------------------------------------------------------------------
// Trace propagation parity (PR 7): a create issued under a client-side
// span must stamp the SAME trace id onto the object's `hpcorc.io/trace`
// annotation whichever transport carried it — in-process, poll-remote,
// or streaming-remote — and the watch event delivering the object must
// carry that annotation unchanged.
// ---------------------------------------------------------------------

#[test]
fn trace_id_stamped_identically_across_all_three_transports() {
    use hpcorc::obs;

    /// Create a pod under a fresh root span; return
    /// (root trace id as stamped hex, annotation wire value, the same
    /// annotation as seen on the watch-delivered event object).
    fn traced_create(api: &dyn ApiClient, name: &str) -> (String, String, String) {
        let rx = api.watch(Some(KIND_POD), 0).expect("watch");
        let guard = obs::span("parity", "traced create");
        let root = guard.context().expect("tracing enabled by default");
        let created = api.create(pod(name)).expect("create");
        drop(guard);
        let annotated = created
            .meta
            .annotation(obs::TRACE_ANNOTATION)
            .expect("create stamps hpcorc.io/trace")
            .to_string();
        assert!(
            created.meta.annotation(obs::CREATED_WALL_ANNOTATION).is_some(),
            "create stamps hpcorc.io/created-wall-ns"
        );
        // The watch event ships the object annotations and all.
        let deadline = Instant::now() + Duration::from_secs(5);
        let from_watch = loop {
            assert!(Instant::now() < deadline, "no watch event for {name}");
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(ev) if ev.object().meta.name == name => {
                    break ev
                        .object()
                        .meta
                        .annotation(obs::TRACE_ANNOTATION)
                        .expect("watch-delivered object keeps the annotation")
                        .to_string();
                }
                _ => continue,
            }
        };
        (format!("{:016x}", root.trace_id), annotated, from_watch)
    }

    let mut runs: Vec<(&str, String, String, String)> = Vec::new();

    let local_api = ApiServer::new(Metrics::new());
    let (root, ann, watched) = traced_create(&local_api, "tr-local");
    runs.push(("in-process", root, ann, watched));

    for (label, force_poll) in [("poll-remote", true), ("streaming-remote", false)] {
        let server = ApiServer::new(Metrics::new());
        let path = parity_sock(&format!("trace-{label}"));
        let mut srv = RedboxServer::start(&path, Shutdown::new(), Metrics::new()).unwrap();
        srv.register("kube.Api", server.rpc_service());
        let remote = RemoteApi::connect(&path)
            .unwrap()
            .with_watch_config(WatchConfig { force_poll, ..WatchConfig::default() });
        let (root, ann, watched) = traced_create(&remote, "tr-remote");
        runs.push((label, root, ann, watched));
        srv.stop();
    }

    for (label, root, ann, watched) in &runs {
        // The annotation is `<trace_id>-<span_id>` of the server-side
        // span; the trace half must be the caller's root trace id.
        let (trace_half, _) = ann.split_once('-').expect("wire format");
        assert_eq!(
            trace_half, root,
            "{label}: object annotation joined a different trace than the caller's span"
        );
        assert_eq!(ann, watched, "{label}: watch delivery altered the annotation");
    }
}

// ---------------------------------------------------------------------
// Disruption API parity (PR 10): the `pods/eviction` subresource and its
// PodDisruptionBudget enforcement must behave — and *error* — byte-
// identically through the in-process server, the poll remote, and the
// streaming remote. A PDB refusal is a typed `DisruptionBudgetExceeded`
// on every transport, not a stringly server error.
// ---------------------------------------------------------------------

#[test]
fn eviction_and_pdb_identical_across_all_three_transports() {
    fn disruption_scenario(api: &dyn ApiClient) -> Vec<String> {
        let mut t = Vec::new();
        let sel = [("disrupt".to_string(), "ha".to_string())];
        for name in ["e0", "e1", "e2"] {
            let mut p = pod(name);
            p.meta.set_label("disrupt", "ha");
            api.create(p).expect("create");
        }
        // Two healthy (Running) replicas; e2 still Pending.
        for name in ["e0", "e1"] {
            api.update_status(KIND_POD, name, &|o| {
                o.status.insert("phase", "Running");
            })
            .expect("us");
        }

        // minAvailable=2: evicting a Running pod would leave 1 < 2.
        api.create(PdbView::build_min_available("ha-budget", &sel, 2)).expect("pdb");
        let err = api.evict("e0", &EvictionMode::Delete).unwrap_err();
        t.push(format!("blocked typed={} msg={err}", err.is_disruption_budget_exceeded()));
        // A Pending victim consumes no budget: allowed even at min=2.
        api.evict("e2", &EvictionMode::Delete).expect("evict pending");
        t.push(format!("pending victim gone={}", api.get(KIND_POD, "e2").unwrap_err().is_not_found()));

        // Relax to minAvailable=1: one Running pod may now be disrupted.
        api.delete(KIND_PODDISRUPTIONBUDGET, "ha-budget").expect("del pdb");
        api.create(PdbView::build_min_available("ha-relaxed", &sel, 1)).expect("pdb2");
        api.evict("e0", &EvictionMode::Delete).expect("evict within budget");
        let pdb = api.get(KIND_PODDISRUPTIONBUDGET, "ha-relaxed").expect("pdb status");
        t.push(format!(
            "after evict allowed={} healthy={}",
            pdb.status.opt_int("disruptionsAllowed").unwrap_or(-1),
            pdb.status.opt_int("currentHealthy").unwrap_or(-1)
        ));
        // The last Running pod is now protected again...
        let err = api.evict("e1", &EvictionMode::Requeue { gate: "parity/requeue".into() }).unwrap_err();
        t.push(format!("last replica blocked typed={}", err.is_disruption_budget_exceeded()));
        // ...until the budget goes away; then Requeue puts it back in the
        // scheduling queue (gated, unbound, Pending) instead of deleting.
        api.delete(KIND_PODDISRUPTIONBUDGET, "ha-relaxed").expect("del pdb2");
        let o = api
            .evict("e1", &EvictionMode::Requeue { gate: "parity/requeue".into() })
            .expect("requeue evict");
        t.push(format!(
            "requeued phase={} gates={:?} node={:?}",
            o.status.opt_str("phase").unwrap_or(""),
            scheduling_gates(&o),
            o.spec.opt_str("nodeName")
        ));
        t
    }

    let local_api = ApiServer::new(Metrics::new());
    let mut transcripts = vec![("in-process", disruption_scenario(&local_api))];

    for (label, force_poll) in [("poll-remote", true), ("streaming-remote", false)] {
        let server = ApiServer::new(Metrics::new());
        let path = parity_sock(&format!("evict-{label}"));
        let mut srv = RedboxServer::start(&path, Shutdown::new(), Metrics::new()).unwrap();
        srv.register("kube.Api", server.rpc_service());
        let remote = RemoteApi::connect(&path)
            .unwrap()
            .with_watch_config(WatchConfig { force_poll, ..WatchConfig::default() });
        transcripts.push((label, disruption_scenario(&remote)));
        srv.stop();
    }

    let (_, reference) = &transcripts[0];
    for (label, t) in &transcripts[1..] {
        assert_eq!(t, reference, "{label} disruption transcript diverged from in-process");
    }
    assert_eq!(reference.len(), 6, "scenario shape changed — update the count");
    assert!(reference[0].starts_with("blocked typed=true"));
    assert!(
        reference[0].contains("ha-budget"),
        "typed error names the violated budget: {}",
        reference[0]
    );
    assert_eq!(reference[1], "pending victim gone=true");
    assert!(reference[3].starts_with("last replica blocked typed=true"));
    assert!(
        reference[5].contains("phase=Pending")
            && reference[5].contains("parity/requeue")
            && reference[5].contains("node=None"),
        "requeue eviction must unbind, re-gate, and reset phase: {}",
        reference[5]
    );
}

// ---------------------------------------------------------------------
// CRD-through-the-API parity (PR 10): registering a
// CustomResourceDefinition at runtime must extend the server's scheme on
// every transport — instances of the new kind and its aliases resolve
// over the wire exactly as in-process.
// ---------------------------------------------------------------------

#[test]
fn crd_registration_identical_through_both_transports() {
    fn crd_scenario(api: &dyn ApiClient) -> Vec<String> {
        let mut t = Vec::new();
        api.create(CrdView::build("parity.io", "v1", "Widget", "widgets", &["wd"]))
            .expect("crd");
        let mut w = KubeObject::new("Widget", "w1", Value::map().with("size", 3u64));
        w.api_version = "parity.io/v1".into();
        api.create(w).expect("widget instance");

        // Aliases resolve server-side: short name, plural, lowercased kind.
        for alias in ["wd", "widgets", "widget"] {
            let o = api.get(alias, "w1").expect("alias get");
            t.push(format!("get {alias} -> {}/{}", o.kind, o.meta.name));
        }
        let listed = api.list("wd", &ListOptions::all()).expect("alias list");
        t.push(format!(
            "list wd items={:?}",
            listed.items.iter().map(|o| o.meta.name.clone()).collect::<Vec<_>>()
        ));
        // `kubectl get crd` surface: the definition itself is API state.
        let crds = api.list(KIND_CUSTOMRESOURCEDEFINITION, &ListOptions::all()).expect("crds");
        t.push(format!(
            "crds={:?}",
            crds.items.iter().map(|o| o.meta.name.clone()).collect::<Vec<_>>()
        ));
        // Identical re-registration is idempotent (apply flavor)...
        api.apply(CrdView::build("parity.io", "v1", "Widget", "widgets", &["wd"]))
            .expect("idempotent re-apply");
        // ...but a conflicting one (same alias, different kind) is refused.
        let err = api
            .create(CrdView::build("parity.io", "v1", "Gadget", "gadgets", &["wd"]))
            .unwrap_err();
        t.push(format!("conflict invalid={}", err.is_invalid()));
        api.delete("wd", "w1").expect("delete via alias");
        t.push(format!("deleted gone={}", api.get("wd", "w1").unwrap_err().is_not_found()));
        t
    }

    let local_api = ApiServer::new(Metrics::new());
    let local = crd_scenario(&local_api);

    let path = parity_sock("crd");
    let mut srv = RedboxServer::start(&path, Shutdown::new(), Metrics::new()).unwrap();
    let remote_server = ApiServer::new(Metrics::new());
    srv.register("kube.Api", remote_server.rpc_service());
    let remote_api = RemoteApi::connect(&path).unwrap();
    let remote = crd_scenario(&remote_api);
    srv.stop();

    assert_eq!(local, remote, "CRD transcripts diverged");
    assert_eq!(local[0], "get wd -> Widget/w1");
    assert!(local[4].contains("widgets.parity.io"), "CRD named <plural>.<group>: {}", local[4]);
    assert_eq!(local[5], "conflict invalid=true");
    assert_eq!(local[6], "deleted gone=true");
}

/// PR 8: an event recorded about a traced object carries the object's
/// trace id — identically through the in-process server and both remote
/// watch transports. The Event object is itself plain API state, so the
/// recorder must work unchanged against any `ApiClient`.
#[test]
fn event_trace_id_agrees_across_all_three_transports() {
    use hpcorc::kube::{EventRecorder, EventView, EVENT_NORMAL, KIND_EVENT};
    use hpcorc::obs;

    /// Create a traced pod, record one event about it, and read the
    /// event back through the same transport. Returns
    /// (root trace id hex, the event's carried trace id).
    fn traced_event(api: &dyn ApiClient, name: &str) -> (String, String) {
        let created = {
            let guard = obs::span("parity", "traced create");
            let _root = guard.context().expect("tracing enabled by default");
            api.create(pod(name)).expect("create")
        };
        let root_hex = created
            .meta
            .annotation(obs::TRACE_ANNOTATION)
            .expect("create stamps the trace")
            .split('-')
            .next()
            .unwrap()
            .to_string();
        let rec = EventRecorder::new("parity-test", Metrics::new());
        rec.event(api, &created, EVENT_NORMAL, "ParityCheck", "event under test")
            .expect("record event");
        let ev = api
            .list(KIND_EVENT, &ListOptions::all())
            .expect("list events")
            .items
            .iter()
            .filter_map(|o| EventView::from_object(o).ok())
            .find(|e| e.regarding_name == name)
            .expect("event readable through the same transport");
        assert_eq!(ev.reporting_controller, "parity-test");
        (root_hex, ev.trace_id().expect("event carries a trace").to_string())
    }

    let local_api = ApiServer::new(Metrics::new());
    let (root, ev) = traced_event(&local_api, "ev-local");
    assert_eq!(root, ev, "in-process: event trace must match the pod's");

    for (label, force_poll) in [("poll-remote", true), ("streaming-remote", false)] {
        let server = ApiServer::new(Metrics::new());
        let path = parity_sock(&format!("event-{label}"));
        let mut srv = RedboxServer::start(&path, Shutdown::new(), Metrics::new()).unwrap();
        srv.register("kube.Api", server.rpc_service());
        let remote = RemoteApi::connect(&path)
            .unwrap()
            .with_watch_config(WatchConfig { force_poll, ..WatchConfig::default() });
        let (root, ev) = traced_event(&remote, "ev-remote");
        assert_eq!(root, ev, "{label}: event trace must match the pod's");
        // The round-trip through the wire must not have re-stamped the
        // event with the recorder's own (absent) context: the server
        // only stamps a trace annotation when none is present.
        srv.stop();
    }
}
