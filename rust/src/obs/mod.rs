//! # Observability layer (PR 7): causal tracing + remote telemetry
//!
//! Everything the control plane emits about itself lives here: a span
//! recorder with cross-process trace propagation ([`trace`]), Prometheus
//! text / JSON rendering of the [`crate::cluster::Metrics`] registry
//! ([`prom`]), and the red-box services that expose both remotely
//! ([`service`]).
//!
//! ## How a trace flows
//!
//! 1. A root span opens wherever work originates — e.g. the CLI's
//!    `kubectl apply`, or a test calling [`span`].
//! 2. The red-box client stamps [`current`] onto every outgoing
//!    [`crate::redbox::proto::Request`] as a `trace` field
//!    (`<trace_id>-<span_id>` hex). Old peers that don't know the field
//!    ignore it; requests without it simply start fresh server-side.
//! 3. The red-box server adopts the wire context around dispatch, so
//!    ApiServer handler spans parent on the remote caller.
//! 4. `ApiServer::create`/`apply` stamp the active context onto the
//!    object as the `hpcorc.io/trace` annotation (plus
//!    `hpcorc.io/created-wall-ns`, the server wall clock). Annotations
//!    ride inside the object through store → WAL → watch → informer, so
//!    every later consumer can rejoin the originating trace.
//! 5. Kueue admission, the scheduler's bind, and the operator's WLM
//!    submission each open spans parented on that annotation — one
//!    connected causal tree from `create` to `run`, reconstructable with
//!    `hpcorc trace <kind>/<name>` or exported via
//!    [`export_chrome_json`] straight into Perfetto.
//!
//! ## Labelled metric families (PR 8): naming rules
//!
//! The registry stores **families with label sets**
//! ([`crate::cluster::Metrics::inc_with`] & friends); the rules that
//! keep the namespace sane:
//!
//! 1. **The family name carries the operation, labels carry the
//!    dimension.** `kube.api.create{gvk="pods"}`, not
//!    `kube.api.create.pods`; `redbox.rpc_ns{method="kube.Api/Create"}`,
//!    not `redbox.rpc.kube.Api.Create_ns`. A new value of a dimension
//!    must never mint a new family.
//! 2. **Low cardinality only.** Label values must be drawn from a small
//!    closed set (GVK plurals, RPC methods, event reasons) — never
//!    object names, trace ids, or anything user-controlled.
//! 3. **Latency families keep the `_ns` suffix** on the family name
//!    (`redbox.rpc_ns{method=...}`), so every series of the family
//!    renders as one Prometheus histogram with merged labels
//!    (`redbox_rpc_ns_bucket{method="...",le="..."}`).
//! 4. **Bare and labelled series may coexist** in one family during a
//!    migration; [`crate::cluster::Metrics::counter_value`] sums the
//!    whole family, so totals survive a call site gaining labels.
//!
//! Exposition is deterministic: families and label sets render in
//! sorted order in both `--prom` and `--json` output.
//!
//! ## Metric-name catalog
//!
//! | Metric | Type | Meaning |
//! |---|---|---|
//! | `redbox.requests` | counter | request frames handled by the server |
//! | `redbox.handle_ns` | histogram | server-side dispatch latency (all methods) |
//! | `redbox.rpc_ns{method}` | histogram | per-RPC-method dispatch latency |
//! | `redbox.streams` / `redbox.stream_items` | counter | server streams opened / items pushed |
//! | `kube.api.<verb>{gvk}` | counter | ApiServer verb calls (create/get/update/...), per resource |
//! | `kube.api.audit_records` | counter | audit records appended |
//! | `kube.store.commit_ns` | histogram | whole store commit (WAL + fan-out + publish) |
//! | `kube.store.wal_append_ns` | histogram | WAL append inside the commit |
//! | `kube.store.fanout_ns` | histogram | watcher fan-out inside the commit |
//! | `kube.informer.deliver_ns` | histogram | informer event apply+forward latency |
//! | `kube.informer.{lists,resyncs,delta_relists,events}` | counter | reflector activity |
//! | `kube.events.emitted{reason}` | counter | cluster Events recorded, per reason |
//! | `kube.events.coalesced{reason}` | counter | Event writes folded into a count bump |
//! | `kube.events.gc` | counter | Events reaped by TTL GC |
//! | `kueue.cycles` | counter | admission cycles run |
//! | `kueue.cycle_ns` | histogram | admission cycle duration |
//! | `kube.sched.cycle_ns` | histogram | scheduler cycle duration |
//! | `kube.sched.bound{outcome}` | counter | pods bound (`outcome="ok"`) |
//! | `kube.sched.bind_failed{outcome}` | counter | failed bind commits (conflict/not_found/transport/error) |
//! | `kube.sched.unschedulable{outcome}` | counter | placement verdicts, per dominant losing predicate |
//! | `kube.sched.pending` | gauge | pods awaiting placement at cycle start |
//! | `kube.sched.index_update_ns` | histogram | fit/score index maintenance per informer delta |
//! | `kube.sched.bind_batch_ns` | histogram | batched bind commit (one batch = one observation) |
//! | `kube.api.update_status_batch` | counter | batched status commits accepted (one per batch) |
//! | `slo.pod_create_to_bound_ns` | histogram | end-to-end pod create→bound latency |
//! | `operator.submit_ns` | histogram | operator → WLM submission latency |
//!
//! Scrape any of these remotely: `hpcorc metrics --socket <sock> --prom`
//! (Prometheus text) or `--json` (structured snapshot); span trees via
//! `hpcorc trace <kind>/<name> --socket <sock>`; the audit trail via
//! `hpcorc audit --socket <sock>` ([`audit`]).
//!
//! ## Overhead
//!
//! `benches/obs.rs` measures span record cost (one mutex push), the
//! disabled path (one atomic load — effectively free), the sampled-out
//! path under `HPCORC_TRACE_SAMPLE` (one modulo on drop), the event
//! recorder hot path, and labelled Prometheus rendering at 10k series.
//! Disable process-wide with [`set_enabled`]; sample with
//! [`set_trace_sample`].

pub mod audit;
pub mod prom;
pub mod service;
pub mod trace;

pub use audit::{
    audit_service, current_actor, push_actor, ActorGuard, AuditLog, AuditRecord,
    AUDIT_RING_CAPACITY, UNATTRIBUTED,
};
pub use prom::{render_json, render_prom, sanitize};
pub use service::{metrics_service, register, spans_service};
pub use trace::{
    attach_span_log, by_trace, chrome_events, chrome_json, clear, current, enabled,
    export_chrome_json, replay_span_log, sampled, set_enabled, set_span_sink, set_trace_sample,
    span, span_from_value, span_to_value, span_with_parent, spans_snapshot, Span, SpanGuard,
    TraceContext, CREATED_WALL_ANNOTATION, TRACE_ANNOTATION,
};
