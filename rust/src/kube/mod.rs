//! Kubernetes-like orchestrator: the big-data cluster of the paper's
//! testbed (Fig. 1). Dynamic object model with CRDs ([`api`]), versioned
//! store with watches ([`store`]), API server with an RPC surface
//! ([`apiserver`]), the scheduler ([`scheduler`]), the node agent
//! ([`kubelet`]), the controller runtime ([`controller`]), a Deployment
//! controller ([`deployment`]), and manifest handling ([`yaml`]).

pub mod api;
pub mod apiserver;
pub mod controller;
pub mod deployment;
pub mod kubelet;
pub mod scheduler;
pub mod store;
pub mod yaml;

pub use api::{
    KubeObject, NodeView, ObjectMeta, PodPhase, PodView, WlmJobView, KIND_DEPLOYMENT,
    KIND_NODE, KIND_POD, KIND_SLURMJOB, KIND_TORQUEJOB, WLM_API_VERSION,
};
pub use apiserver::{ApiServer, RemoteApi};
pub use controller::{Controller, ControllerRunner, Reconcile};
pub use deployment::DeploymentController;
pub use kubelet::Kubelet;
pub use scheduler::KubeScheduler;
pub use store::{Store, WatchEvent};
