//! Span recorder: trace contexts, RAII span guards, and a bounded ring
//! of completed spans exportable as Chrome trace-event JSON.
//!
//! A **trace** is one causal tree of work identified by a 64-bit
//! `trace_id`; each unit of work inside it is a **span** with its own
//! `span_id` and a `parent` link. Context lives in a thread-local stack:
//! [`span`] opens a child of whatever is current (or a new root),
//! [`span_with_parent`] adopts a context that arrived from elsewhere
//! (the red-box wire, an object annotation), and [`current`] reads the
//! active context so call sites — the red-box client, the logger — can
//! stamp it onto whatever they emit.
//!
//! Completed spans land in a global fixed-capacity ring under one mutex;
//! pushes are O(1) and allocation-free once the ring is warm, so the
//! recorder is safe to leave on inside hot loops. When tracing is
//! disabled ([`set_enabled`]) every guard is a no-op costing one atomic
//! load — benchmarked in `benches/obs.rs`.

//!
//! **Sampling** (PR 8): `HPCORC_TRACE_SAMPLE=N` (or [`set_trace_sample`])
//! records 1-in-N root traces. The verdict is a pure function of the
//! `trace_id` ([`sampled`]), so every child span — including spans
//! adopted across the red-box wire or an object annotation — follows its
//! root's verdict and sampled traces stay *connected*. Unsampled spans
//! still push/pop thread-local context (propagation is unaffected); only
//! the ring write is skipped.
//!
//! **Durability** (PR 8): a process-wide span sink ([`set_span_sink`])
//! observes every recorded span — the testbed attaches a WAL-style
//! JSON-line file sink ([`attach_span_log`]) next to the store's WAL and
//! replays it into the ring on boot ([`replay_span_log`]), so
//! `hpcorc trace KIND/NAME` reconstructs timelines across a restart.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Object annotation carrying the originating trace context
/// (`<trace_id>-<span_id>` in hex, the same rendering as the wire field)
/// so every later hop of an object's lifecycle — admission, scheduling,
/// the operator — can parent its spans on the create that started it.
pub const TRACE_ANNOTATION: &str = "hpcorc.io/trace";

/// Object annotation holding the server's wall clock (nanoseconds since
/// the epoch) at create time — what the scheduler subtracts from to
/// observe the end-to-end create→bound SLO histogram regardless of which
/// transport carried the create.
pub const CREATED_WALL_ANNOTATION: &str = "hpcorc.io/created-wall-ns";

/// Completed spans retained in the ring (oldest overwritten first).
pub const RING_CAPACITY: usize = 8192;

/// The identity of one span within one trace. `parent == 0` means root.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceContext {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent: u64,
}

impl TraceContext {
    /// Wire rendering carried on red-box requests and in the
    /// [`TRACE_ANNOTATION`]: `<16-hex trace_id>-<16-hex span_id>`. The
    /// receiver treats the sender's span as its parent.
    pub fn to_wire(&self) -> String {
        format!("{:016x}-{:016x}", self.trace_id, self.span_id)
    }

    /// Parse the wire rendering; `None` on anything malformed (old peers
    /// that never send the field simply yield no context).
    pub fn parse_wire(s: &str) -> Option<TraceContext> {
        let (t, sp) = s.split_once('-')?;
        let trace_id = u64::from_str_radix(t, 16).ok()?;
        let span_id = u64::from_str_radix(sp, 16).ok()?;
        if trace_id == 0 || span_id == 0 {
            return None;
        }
        Some(TraceContext { trace_id, span_id, parent: 0 })
    }
}

/// One completed span as recorded in the ring.
#[derive(Debug, Clone)]
pub struct Span {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent: u64,
    /// Component that opened the span (Chrome `cat`), e.g. `apiserver`.
    pub component: String,
    /// Operation name (Chrome `name`), e.g. `kube.Api/Create`.
    pub name: String,
    /// Wall-clock start, microseconds since the Unix epoch (Chrome `ts`).
    pub start_us: u64,
    /// Duration in microseconds (Chrome `dur`).
    pub dur_us: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(true);
static NEXT: AtomicU64 = AtomicU64::new(1);
static SEED: AtomicU64 = AtomicU64::new(0);
/// 0 = read `HPCORC_TRACE_SAMPLE` on first use; >= 1 afterwards.
static SAMPLE_N: AtomicU64 = AtomicU64::new(0);
static SINK_SET: AtomicBool = AtomicBool::new(false);

type SpanSink = dyn Fn(&Span) + Send + Sync;
static SINK: Mutex<Option<Arc<SpanSink>>> = Mutex::new(None);

struct Ring {
    spans: Vec<Span>,
    /// Next overwrite position once the ring is full.
    next: usize,
}

static RING: Mutex<Ring> = Mutex::new(Ring { spans: Vec::new(), next: 0 });

thread_local! {
    static STACK: RefCell<Vec<TraceContext>> = const { RefCell::new(Vec::new()) };
}

/// Whether spans are being recorded.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on/off process-wide. Off: every guard becomes a
/// no-op and [`current`] keeps answering for already-open spans only.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

fn sample_n() -> u64 {
    let n = SAMPLE_N.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let n = std::env::var("HPCORC_TRACE_SAMPLE")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1);
    // First writer wins; every thread then agrees on one rate.
    let _ = SAMPLE_N.compare_exchange(0, n, Ordering::Relaxed, Ordering::Relaxed);
    SAMPLE_N.load(Ordering::Relaxed)
}

/// Set the trace sampling rate: record 1-in-`n` root traces (`n <= 1`
/// records everything). Overrides `HPCORC_TRACE_SAMPLE`.
pub fn set_trace_sample(n: u64) {
    SAMPLE_N.store(n.max(1), Ordering::Relaxed);
}

/// Whether a trace is recorded under the current sampling rate. A pure
/// function of the trace id, so children (local or adopted across a
/// wire/annotation hop) always share their root's verdict.
pub fn sampled(trace_id: u64) -> bool {
    let n = sample_n();
    n <= 1 || trace_id % n == 0
}

/// Install (or with `None`, remove) the process-wide span sink, invoked
/// for every span recorded into the ring. Used for WAL-style span
/// durability; see [`attach_span_log`].
pub fn set_span_sink(sink: Option<Arc<SpanSink>>) {
    let mut s = SINK.lock().unwrap();
    SINK_SET.store(sink.is_some(), Ordering::Relaxed);
    *s = sink;
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn seed() -> u64 {
    let s = SEED.load(Ordering::Relaxed);
    if s != 0 {
        return s;
    }
    let wall =
        SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as u64;
    let mixed = splitmix64(wall ^ ((std::process::id() as u64) << 32)) | 1;
    // First writer wins so every thread derives ids from one seed.
    let _ = SEED.compare_exchange(0, mixed, Ordering::Relaxed, Ordering::Relaxed);
    SEED.load(Ordering::Relaxed)
}

/// A fresh non-zero id, unique within the process and seeded so two
/// processes (daemon + CLI) do not collide in practice.
fn new_id() -> u64 {
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let id = splitmix64(seed().wrapping_add(n));
    if id == 0 {
        1
    } else {
        id
    }
}

/// The active trace context on this thread, if any.
pub fn current() -> Option<TraceContext> {
    STACK.with(|s| s.borrow().last().copied())
}

/// RAII span: pushed onto the thread's context stack at creation,
/// popped and recorded into the ring on drop. Obtained from [`span`] /
/// [`span_with_parent`]; a disabled recorder hands out inert guards.
pub struct SpanGuard {
    active: Option<ActiveSpan>,
}

struct ActiveSpan {
    ctx: TraceContext,
    component: String,
    name: String,
    start_us: u64,
    t0: Instant,
}

impl SpanGuard {
    /// The context this guard pushed (`None` for a disabled no-op guard).
    pub fn context(&self) -> Option<TraceContext> {
        self.active.as_ref().map(|a| a.ctx)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(a) = self.active.take() else { return };
        STACK.with(|s| {
            let mut st = s.borrow_mut();
            // Pop our own frame; tolerate a foreign top (mismatched drop
            // order across an unwind) by searching from the back.
            if let Some(pos) = st.iter().rposition(|c| c.span_id == a.ctx.span_id) {
                st.remove(pos);
            }
        });
        // Context propagated regardless; only the recording is sampled.
        if !sampled(a.ctx.trace_id) {
            return;
        }
        push_span(Span {
            trace_id: a.ctx.trace_id,
            span_id: a.ctx.span_id,
            parent: a.ctx.parent,
            component: a.component,
            name: a.name,
            start_us: a.start_us,
            dur_us: a.t0.elapsed().as_micros() as u64,
        });
    }
}

fn wall_us() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_micros() as u64
}

fn open(component: &str, name: &str, parent: Option<TraceContext>) -> SpanGuard {
    if !enabled() {
        return SpanGuard { active: None };
    }
    let ctx = match parent {
        Some(p) => TraceContext { trace_id: p.trace_id, span_id: new_id(), parent: p.span_id },
        None => {
            let id = new_id();
            TraceContext { trace_id: id, span_id: id, parent: 0 }
        }
    };
    STACK.with(|s| s.borrow_mut().push(ctx));
    SpanGuard {
        active: Some(ActiveSpan {
            ctx,
            component: component.to_string(),
            name: name.to_string(),
            start_us: wall_us(),
            t0: Instant::now(),
        }),
    }
}

/// Open a span as a child of the thread's current context (or a new root
/// when none is active).
pub fn span(component: &str, name: &str) -> SpanGuard {
    open(component, name, current())
}

/// Open a span parented on an explicit context — the adoption point for
/// contexts that crossed a boundary (red-box wire field, object
/// annotation). `None` behaves like [`span`].
pub fn span_with_parent(component: &str, name: &str, parent: Option<TraceContext>) -> SpanGuard {
    open(component, name, parent.or_else(current))
}

fn push_span(s: Span) {
    if SINK_SET.load(Ordering::Relaxed) {
        let sink = SINK.lock().unwrap().clone();
        if let Some(sink) = sink {
            sink(&s);
        }
    }
    push_span_ring_only(s);
}

/// Ring insert without the sink hop — what [`replay_span_log`] uses so a
/// boot-time replay never re-appends to the log it is reading.
fn push_span_ring_only(s: Span) {
    let mut r = RING.lock().unwrap();
    if r.spans.len() < RING_CAPACITY {
        r.spans.push(s);
    } else {
        let i = r.next;
        r.spans[i] = s;
        r.next = (i + 1) % RING_CAPACITY;
    }
}

/// Every span currently retained, oldest first.
pub fn spans_snapshot() -> Vec<Span> {
    let r = RING.lock().unwrap();
    let mut out = Vec::with_capacity(r.spans.len());
    if r.spans.len() == RING_CAPACITY {
        out.extend_from_slice(&r.spans[r.next..]);
        out.extend_from_slice(&r.spans[..r.next]);
    } else {
        out.extend_from_slice(&r.spans);
    }
    out
}

/// Retained spans belonging to one trace, sorted by start time.
pub fn by_trace(trace_id: u64) -> Vec<Span> {
    let mut out: Vec<Span> =
        spans_snapshot().into_iter().filter(|s| s.trace_id == trace_id).collect();
    out.sort_by_key(|s| (s.start_us, s.span_id));
    out
}

/// Drop every retained span (test isolation).
pub fn clear() {
    let mut r = RING.lock().unwrap();
    r.spans.clear();
    r.next = 0;
}

/// Render spans as a Chrome trace-event JSON array (complete `"X"`
/// events) — loads directly into Perfetto / `chrome://tracing`. Each
/// trace renders as its own `tid` track; parent/span ids travel in
/// `args` so the causal tree survives the export.
pub fn chrome_json(spans: &[Span]) -> String {
    crate::encoding::json::to_string(&chrome_events(spans))
}

/// The same export as a [`Value`] array — what `obs.Spans` serves over
/// red-box so remote consumers get structure, not a string to re-parse.
pub fn chrome_events(spans: &[Span]) -> crate::encoding::Value {
    use crate::encoding::Value;
    let events: Vec<Value> = spans
        .iter()
        .map(|s| {
            Value::map()
                .with("name", s.name.clone())
                .with("cat", s.component.clone())
                .with("ph", "X")
                .with("ts", s.start_us)
                .with("dur", s.dur_us.max(1))
                .with("pid", 1u64)
                .with("tid", s.trace_id & 0x7fff_ffff)
                .with(
                    "args",
                    Value::map()
                        .with("trace_id", format!("{:016x}", s.trace_id))
                        .with("span_id", format!("{:016x}", s.span_id))
                        .with("parent", format!("{:016x}", s.parent)),
                )
        })
        .collect();
    Value::Seq(events)
}

/// [`chrome_json`] over the whole ring.
pub fn export_chrome_json() -> String {
    chrome_json(&spans_snapshot())
}

// ---------------------------------------------------------------------
// Span durability (PR 8): JSON-line log next to the store's WAL.
// ---------------------------------------------------------------------

/// One span as a JSON-line record (ids in hex, matching the wire form).
pub fn span_to_value(s: &Span) -> crate::encoding::Value {
    crate::encoding::Value::map()
        .with("trace", format!("{:016x}", s.trace_id))
        .with("span", format!("{:016x}", s.span_id))
        .with("parent", format!("{:016x}", s.parent))
        .with("cat", s.component.clone())
        .with("name", s.name.clone())
        .with("ts", s.start_us)
        .with("dur", s.dur_us)
}

/// Decode one [`span_to_value`] record; `None` on anything malformed
/// (a torn tail line from a crash mid-append just ends the replay).
pub fn span_from_value(v: &crate::encoding::Value) -> Option<Span> {
    let hex = |k: &str| v.opt_str(k).and_then(|s| u64::from_str_radix(s, 16).ok());
    Some(Span {
        trace_id: hex("trace")?,
        span_id: hex("span")?,
        parent: hex("parent")?,
        component: v.opt_str("cat")?.to_string(),
        name: v.opt_str("name")?.to_string(),
        start_us: v.opt_int("ts")? as u64,
        dur_us: v.opt_int("dur")? as u64,
    })
}

/// Install a file sink appending one JSON line per recorded span to
/// `path` (created if missing, appended otherwise). Replaces any prior
/// sink. The write is flushed per span — the same durability stance as
/// the store WAL's append-on-commit.
pub fn attach_span_log(path: &std::path::Path) -> crate::util::Result<()> {
    use std::io::Write;
    let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    let file = Mutex::new(file);
    set_span_sink(Some(Arc::new(move |s: &Span| {
        let line = crate::encoding::json::to_string(&span_to_value(s));
        let mut f = file.lock().unwrap();
        let _ = writeln!(f, "{line}");
        let _ = f.flush();
    })));
    Ok(())
}

/// Replay a span log into the ring (oldest first; only the newest
/// [`RING_CAPACITY`] survive, matching live behavior). Malformed lines
/// are skipped. Returns how many spans were restored. Call **before**
/// [`attach_span_log`] on the same file, or the replay re-appends.
pub fn replay_span_log(path: &std::path::Path) -> usize {
    let Ok(text) = std::fs::read_to_string(path) else { return 0 };
    let mut spans: Vec<Span> = text
        .lines()
        .filter_map(|l| crate::encoding::json::parse(l).ok())
        .filter_map(|v| span_from_value(&v))
        .collect();
    if spans.len() > RING_CAPACITY {
        spans.drain(..spans.len() - RING_CAPACITY);
    }
    let n = spans.len();
    for s in spans {
        push_span_ring_only(s);
    }
    n
}

/// The recorder is process-global; tests (here and in sibling modules)
/// that toggle the enable flag or inspect the ring serialize on this.
#[cfg(test)]
pub(crate) static TEST_SERIAL: Mutex<()> = Mutex::new(());

#[cfg(test)]
pub(crate) fn test_serial() -> std::sync::MutexGuard<'static, ()> {
    TEST_SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serial() -> std::sync::MutexGuard<'static, ()> {
        test_serial()
    }

    #[test]
    fn wire_roundtrip() {
        let ctx = TraceContext { trace_id: 0xdead_beef, span_id: 42, parent: 7 };
        let wire = ctx.to_wire();
        let back = TraceContext::parse_wire(&wire).unwrap();
        assert_eq!(back.trace_id, ctx.trace_id);
        assert_eq!(back.span_id, ctx.span_id);
        assert_eq!(back.parent, 0, "wire carries no grandparent");
        assert!(TraceContext::parse_wire("junk").is_none());
        assert!(TraceContext::parse_wire("0-0").is_none());
        assert!(TraceContext::parse_wire("12x-34").is_none());
    }

    #[test]
    fn nesting_links_parents() {
        let _s = serial();
        set_enabled(true);
        let root = span("test", "root");
        let root_ctx = root.context().unwrap();
        assert_eq!(root_ctx.parent, 0);
        assert_eq!(root_ctx.trace_id, root_ctx.span_id);
        {
            let child = span("test", "child");
            let c = child.context().unwrap();
            assert_eq!(c.trace_id, root_ctx.trace_id);
            assert_eq!(c.parent, root_ctx.span_id);
            assert_eq!(current().unwrap().span_id, c.span_id);
        }
        // Child popped; root is current again.
        assert_eq!(current().unwrap().span_id, root_ctx.span_id);
        drop(root);
        assert!(current().is_none());
        let tree = by_trace(root_ctx.trace_id);
        assert_eq!(tree.len(), 2);
        assert!(tree.iter().any(|s| s.name == "root" && s.parent == 0));
        assert!(
            tree.iter().any(|s| s.name == "child" && s.parent == root_ctx.span_id),
            "child links to root"
        );
    }

    #[test]
    fn adoption_joins_the_remote_trace() {
        let _s = serial();
        set_enabled(true);
        let remote = TraceContext { trace_id: 77, span_id: 99, parent: 0 };
        let g = span_with_parent("test", "handler", Some(remote));
        let ctx = g.context().unwrap();
        assert_eq!(ctx.trace_id, 77);
        assert_eq!(ctx.parent, 99);
        assert_ne!(ctx.span_id, 99, "adoption mints a fresh span id");
    }

    #[test]
    fn disabled_guards_are_inert() {
        let _s = serial();
        set_enabled(false);
        let g = span("test", "nope");
        assert!(g.context().is_none());
        assert!(current().is_none());
        drop(g);
        set_enabled(true);
    }

    #[test]
    fn chrome_export_is_valid_json() {
        let _s = serial();
        set_enabled(true);
        {
            let _g = span("test", "export-me");
        }
        let json = export_chrome_json();
        let v = crate::encoding::json::parse(&json).unwrap();
        let events = v.as_seq().expect("top-level array");
        assert!(!events.is_empty());
        let e = events.iter().find(|e| e.opt_str("name") == Some("export-me")).unwrap();
        assert_eq!(e.opt_str("ph"), Some("X"));
        assert!(e.get("ts").is_some() && e.get("dur").is_some());
        assert!(e.get("args").unwrap().opt_str("trace_id").is_some());
    }

    #[test]
    fn sampling_records_one_in_n_and_children_follow_the_root() {
        let _s = serial();
        set_enabled(true);
        clear();
        set_trace_sample(2);
        // Trace ids are pseudo-random, so hunt until both verdicts seen.
        let (mut kept, mut dropped) = (None, None);
        for _ in 0..512 {
            let g = span("sample-test", "root");
            let ctx = g.context().unwrap();
            assert!(current().is_some(), "context propagates even when unsampled");
            {
                let _c = span("sample-test", "child");
            }
            drop(g);
            if sampled(ctx.trace_id) {
                kept.get_or_insert(ctx.trace_id);
            } else {
                dropped.get_or_insert(ctx.trace_id);
            }
            if kept.is_some() && dropped.is_some() {
                break;
            }
        }
        let kept = kept.expect("a sampled trace in 512 draws");
        let dropped = dropped.expect("an unsampled trace in 512 draws");
        assert_eq!(by_trace(kept).len(), 2, "sampled root records root + child");
        assert!(by_trace(dropped).is_empty(), "unsampled trace records nothing");
        // An adopted span (wire/annotation hop) follows its root's verdict.
        let remote = TraceContext { trace_id: dropped, span_id: 7, parent: 0 };
        {
            let _g = span_with_parent("sample-test", "adopted", Some(remote));
        }
        assert!(by_trace(dropped).is_empty(), "adoption keeps the root verdict");
        set_trace_sample(1);
    }

    #[test]
    fn span_log_replays_across_a_restart() {
        let _s = serial();
        set_enabled(true);
        set_trace_sample(1);
        let path = std::env::temp_dir()
            .join(format!("hpcorc-span-log-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        attach_span_log(&path).unwrap();
        let tid = {
            let g = span("persist-test", "boot-span");
            let t = g.context().unwrap().trace_id;
            drop(g);
            t
        };
        set_span_sink(None);
        clear(); // the "restart": the in-memory ring is gone
        assert!(by_trace(tid).is_empty());
        assert!(replay_span_log(&path) >= 1);
        let got = by_trace(tid);
        assert_eq!(got.len(), 1, "replay restores the persisted span");
        assert_eq!(got[0].name, "boot-span");
        assert_eq!(got[0].component, "persist-test");
        // Codec round trip is exact.
        let back = span_from_value(&span_to_value(&got[0])).unwrap();
        assert_eq!(back.span_id, got[0].span_id);
        assert_eq!(back.start_us, got[0].start_us);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let _s = serial();
        // Use a private burst larger than capacity and check bounds only
        // (other tests share the ring).
        set_enabled(true);
        for i in 0..(RING_CAPACITY + 10) {
            push_span(Span {
                trace_id: 1,
                span_id: i as u64 + 1,
                parent: 0,
                component: "t".into(),
                name: "n".into(),
                start_us: i as u64,
                dur_us: 1,
            });
        }
        assert!(spans_snapshot().len() <= RING_CAPACITY);
        clear();
        assert!(spans_snapshot().is_empty());
    }
}
