//! Login-node red-box services: the WLM side of the bridge.
//!
//! "Torque-Operator invokes the Torque binary qsub which submits PBS job to
//! the Torque cluster" (paper §III-B). These services are that invocation
//! surface, exported over the red-box Unix socket: `torque.Workload/*`
//! backed by pbs_server, `slurm.Workload/*` backed by slurmctld (the
//! WLM-Operator baseline). The [`WlmBridge`] trait is the client-side
//! mirror the operators program against.

use crate::encoding::Value;
use crate::pbs::{JobState, PbsServer};
use crate::redbox::{RedboxClient, Service};
use crate::slurm::{SlurmJobState, Slurmctld};
use crate::util::{Error, Result};
use std::sync::Arc;

/// WLM-agnostic job status as the operator sees it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WlmStatus {
    Queued,
    Running,
    Completed,
    Failed { exit_code: i32 },
    Cancelled,
    Timeout,
}

impl WlmStatus {
    pub fn terminal(&self) -> bool {
        !matches!(self, WlmStatus::Queued | WlmStatus::Running)
    }

    pub fn encode(&self) -> Value {
        match self {
            WlmStatus::Queued => Value::map().with("state", "queued"),
            WlmStatus::Running => Value::map().with("state", "running"),
            WlmStatus::Completed => Value::map().with("state", "completed"),
            WlmStatus::Failed { exit_code } => Value::map()
                .with("state", "failed")
                .with("exitCode", *exit_code as i64),
            WlmStatus::Cancelled => Value::map().with("state", "cancelled"),
            WlmStatus::Timeout => Value::map().with("state", "timeout"),
        }
    }

    pub fn decode(v: &Value) -> Result<WlmStatus> {
        Ok(match v.req_str("state")? {
            "queued" => WlmStatus::Queued,
            "running" => WlmStatus::Running,
            "completed" => WlmStatus::Completed,
            "failed" => WlmStatus::Failed {
                exit_code: v.opt_int("exitCode").unwrap_or(1) as i32,
            },
            "cancelled" => WlmStatus::Cancelled,
            "timeout" => WlmStatus::Timeout,
            s => return Err(Error::rpc(format!("unknown wlm state `{s}`"))),
        })
    }
}

/// What an operator needs from a workload manager.
pub trait WlmBridge: Send + Sync {
    /// Submit a batch script; returns the WLM job id as a string.
    fn submit(&self, script: &str, user: &str) -> Result<String>;
    fn status(&self, job_id: &str) -> Result<WlmStatus>;
    fn cancel(&self, job_id: &str) -> Result<()>;
    /// Read a file from the WLM cluster's shared FS (results collection).
    fn read_file(&self, path: &str) -> Result<String>;
    /// Write a file into the WLM cluster's shared FS (results staging).
    fn write_file(&self, path: &str, content: &str) -> Result<()>;
    /// Queue/partition names, default first.
    fn queues(&self) -> Result<Vec<String>>;
}

// ------------------------------------------------------------ torque side

/// Red-box service backed by pbs_server (runs on the login node).
pub struct TorqueLoginService {
    server: PbsServer,
}

impl TorqueLoginService {
    pub fn new(server: PbsServer) -> Arc<Self> {
        Arc::new(TorqueLoginService { server })
    }
}

fn pbs_status(job: &crate::pbs::Job) -> WlmStatus {
    match job.state {
        JobState::Queued | JobState::Held => WlmStatus::Queued,
        JobState::Running => WlmStatus::Running,
        JobState::Completed => {
            if job.walltime_exceeded {
                WlmStatus::Timeout
            } else if job.cancelled {
                WlmStatus::Cancelled
            } else if job.exit_code.unwrap_or(1) == 0 {
                WlmStatus::Completed
            } else {
                WlmStatus::Failed { exit_code: job.exit_code.unwrap_or(1) }
            }
        }
    }
}

impl Service for TorqueLoginService {
    fn call(&self, method: &str, body: &Value) -> Result<Value> {
        match method {
            "SubmitJob" => {
                let id = self
                    .server
                    .qsub(body.req_str("script")?, body.opt_str("user").unwrap_or("operator"))?;
                Ok(Value::map().with("jobId", id.to_string()))
            }
            "JobStatus" => {
                let seq = parse_seq(body.req_str("jobId")?)?;
                let job = self.server.qstat_job(seq)?;
                Ok(pbs_status(&job).encode())
            }
            "CancelJob" => {
                let seq = parse_seq(body.req_str("jobId")?)?;
                self.server.qdel(seq)?;
                Ok(Value::Null)
            }
            "ReadFile" => {
                let content = self.server.fs().read_string(body.req_str("path")?)?;
                Ok(Value::map().with("content", content))
            }
            "WriteFile" => {
                self.server
                    .fs()
                    .write(body.req_str("path")?, body.req_str("content")?.as_bytes())?;
                Ok(Value::Null)
            }
            "Queues" => {
                let mut names = self.server.queues().names();
                // default first
                if let Ok(d) = self.server.queues().resolve(None) {
                    let d = d.name.clone();
                    names.retain(|n| n != &d);
                    names.insert(0, d);
                }
                Ok(Value::Seq(names.into_iter().map(Value::Str).collect()))
            }
            other => Err(Error::rpc(format!("torque.Workload has no method `{other}`"))),
        }
    }
}

fn parse_seq(job_id: &str) -> Result<u64> {
    // Accept both `42.torque-head` and bare `42`.
    crate::util::JobId::parse(job_id)
        .map(|j| j.seq)
        .or_else(|| job_id.parse().ok())
        .ok_or_else(|| Error::rpc(format!("bad job id `{job_id}`")))
}

// ------------------------------------------------------------- slurm side

/// Red-box service backed by slurmctld.
pub struct SlurmLoginService {
    ctld: Slurmctld,
}

impl SlurmLoginService {
    pub fn new(ctld: Slurmctld) -> Arc<Self> {
        Arc::new(SlurmLoginService { ctld })
    }
}

fn slurm_status(job: &crate::slurm::SlurmJob) -> WlmStatus {
    match job.state {
        SlurmJobState::Pending => WlmStatus::Queued,
        SlurmJobState::Running => WlmStatus::Running,
        SlurmJobState::Completed => WlmStatus::Completed,
        SlurmJobState::Failed => WlmStatus::Failed { exit_code: job.exit_code.unwrap_or(1) },
        SlurmJobState::Cancelled => WlmStatus::Cancelled,
        SlurmJobState::Timeout => WlmStatus::Timeout,
    }
}

impl Service for SlurmLoginService {
    fn call(&self, method: &str, body: &Value) -> Result<Value> {
        match method {
            "SubmitJob" => {
                let id = self
                    .ctld
                    .sbatch(body.req_str("script")?, body.opt_str("user").unwrap_or("operator"))?;
                Ok(Value::map().with("jobId", id.to_string()))
            }
            "JobStatus" => {
                let id: u64 = body
                    .req_str("jobId")?
                    .parse()
                    .map_err(|_| Error::rpc("bad slurm job id"))?;
                let job = self.ctld.scontrol_show(id)?;
                Ok(slurm_status(&job).encode())
            }
            "CancelJob" => {
                let id: u64 = body
                    .req_str("jobId")?
                    .parse()
                    .map_err(|_| Error::rpc("bad slurm job id"))?;
                self.ctld.scancel(id)?;
                Ok(Value::Null)
            }
            "ReadFile" => {
                let content = self.ctld.fs().read_string(body.req_str("path")?)?;
                Ok(Value::map().with("content", content))
            }
            "WriteFile" => {
                self.ctld
                    .fs()
                    .write(body.req_str("path")?, body.req_str("content")?.as_bytes())?;
                Ok(Value::Null)
            }
            "Queues" => {
                let mut names: Vec<String> =
                    self.ctld.partitions().iter().map(|p| p.name.clone()).collect();
                if let Some(d) = self.ctld.partitions().iter().find(|p| p.is_default) {
                    let d = d.name.clone();
                    names.retain(|n| n != &d);
                    names.insert(0, d);
                }
                Ok(Value::Seq(names.into_iter().map(Value::Str).collect()))
            }
            other => Err(Error::rpc(format!("slurm.Workload has no method `{other}`"))),
        }
    }
}

// --------------------------------------------------------- client bridges

/// Client-side bridge over red-box for a given service prefix
/// (`torque.Workload` / `slurm.Workload`).
pub struct RedboxBridge {
    client: RedboxClient,
    service: String,
}

impl RedboxBridge {
    pub fn torque(client: RedboxClient) -> Self {
        RedboxBridge { client, service: "torque.Workload".into() }
    }

    pub fn slurm(client: RedboxClient) -> Self {
        RedboxBridge { client, service: "slurm.Workload".into() }
    }

    fn call(&self, method: &str, body: Value) -> Result<Value> {
        self.client.call(&format!("{}/{method}", self.service), body)
    }
}

impl WlmBridge for RedboxBridge {
    fn submit(&self, script: &str, user: &str) -> Result<String> {
        let out =
            self.call("SubmitJob", Value::map().with("script", script).with("user", user))?;
        Ok(out.req_str("jobId")?.to_string())
    }

    fn status(&self, job_id: &str) -> Result<WlmStatus> {
        WlmStatus::decode(&self.call("JobStatus", Value::map().with("jobId", job_id))?)
    }

    fn cancel(&self, job_id: &str) -> Result<()> {
        self.call("CancelJob", Value::map().with("jobId", job_id))?;
        Ok(())
    }

    fn read_file(&self, path: &str) -> Result<String> {
        let out = self.call("ReadFile", Value::map().with("path", path))?;
        Ok(out.req_str("content")?.to_string())
    }

    fn write_file(&self, path: &str, content: &str) -> Result<()> {
        self.call("WriteFile", Value::map().with("path", path).with("content", content))?;
        Ok(())
    }

    fn queues(&self) -> Result<Vec<String>> {
        let out = self.call("Queues", Value::Null)?;
        Ok(out
            .as_seq()
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Metrics, NodeRole, NodeSpec, Resources, SharedFs};
    use crate::pbs::PbsConfig;
    use crate::redbox::RedboxServer;
    use crate::rt::{Shutdown, Timers};
    use crate::sched::EasyBackfill;
    use crate::singularity::{ImageRegistry, Runtime, RuntimeKind};
    use std::time::Duration;

    fn boot_torque(sd: &Shutdown) -> PbsServer {
        let (timers, _) = Timers::start(sd.clone());
        let runtime = Runtime::new(
            RuntimeKind::Singularity,
            ImageRegistry::with_defaults(),
            Metrics::new(),
        );
        let nodes = vec![
            NodeSpec::new("cn01", NodeRole::TorqueCompute, Resources::cores(8, 32 << 30)),
            NodeSpec::new("cn02", NodeRole::TorqueCompute, Resources::cores(8, 32 << 30)),
        ];
        let mut cfg = PbsConfig::default();
        cfg.time_scale = 0.001;
        cfg.sched_period = Duration::from_millis(2);
        PbsServer::start(
            cfg,
            nodes,
            runtime,
            SharedFs::new(),
            Box::new(EasyBackfill),
            timers,
            Metrics::new(),
            sd.clone(),
        )
        .unwrap()
    }

    #[test]
    fn torque_bridge_full_cycle_over_socket() {
        let sd = Shutdown::new();
        let srv_pbs = boot_torque(&sd);
        let sock = std::env::temp_dir()
            .join(format!("hpcorc-redboxsvc-{}.sock", std::process::id()));
        let mut rb = RedboxServer::start(&sock, sd.clone(), Metrics::new()).unwrap();
        rb.register("torque.Workload", TorqueLoginService::new(srv_pbs.clone()));
        let bridge = RedboxBridge::torque(RedboxClient::connect(&sock).unwrap());

        assert_eq!(bridge.queues().unwrap(), vec!["batch".to_string()]);
        let id = bridge
            .submit(
                "#PBS -o $HOME/low.out\nsingularity run lolcow_latest.sif\n",
                "kube-operator",
            )
            .unwrap();
        assert!(id.ends_with(".torque-head"), "{id}");
        // Poll to terminal.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let st = bridge.status(&id).unwrap();
            if st.terminal() {
                assert_eq!(st, WlmStatus::Completed);
                break;
            }
            assert!(std::time::Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(2));
        }
        let out = bridge.read_file("$HOME/low.out").unwrap();
        assert!(out.contains("Moo"));
        bridge.write_file("$HOME/staged.txt", "copied").unwrap();
        assert_eq!(srv_pbs.fs().read_string("$HOME/staged.txt").unwrap(), "copied");
        // Cancel path on a fresh long job.
        let id2 = bridge.submit("sleep 600\n", "op").unwrap();
        bridge.cancel(&id2).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let st = bridge.status(&id2).unwrap();
            if st.terminal() {
                assert_eq!(st, WlmStatus::Cancelled);
                break;
            }
            assert!(std::time::Instant::now() < deadline);
            std::thread::sleep(Duration::from_millis(2));
        }
        // Error transport: unknown job.
        assert!(bridge.status("9999.torque-head").is_err());
        rb.stop();
        sd.trigger();
    }

    #[test]
    fn status_mapping() {
        for (state, expect_terminal) in [
            (WlmStatus::Queued, false),
            (WlmStatus::Running, false),
            (WlmStatus::Completed, true),
            (WlmStatus::Failed { exit_code: 2 }, true),
            (WlmStatus::Cancelled, true),
            (WlmStatus::Timeout, true),
        ] {
            assert_eq!(state.terminal(), expect_terminal);
            assert_eq!(WlmStatus::decode(&state.encode()).unwrap(), state);
        }
    }
}
