//! Quickstart: the paper's test case (§IV, Figs. 3–5), end to end.
//!
//! Boots the hybrid testbed (Fig. 1), applies the verbatim `cow_job.yaml`
//! manifest (Fig. 3), polls `kubectl get torquejob` (Fig. 4), and prints
//! the lolcow output staged by the results pod (Fig. 5).
//!
//! Run: `cargo run --release --example quickstart`

use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::{Api, ListOptions, NodeView, PodView, WlmJobView};
use hpcorc::util::fmt_age;
use std::time::Duration;

fn main() {
    println!("=== hpcorc quickstart: Torque-Operator test case (paper §IV) ===\n");
    println!("Table I components: kube + pbs | singularity + singularity-cri | operator | rustc+jax-aot\n");

    let mut cfg = TestbedConfig::default();
    cfg.operator_deployment = true; // the operator's 4 service containers (§III-B)
    let tb = Testbed::start(cfg).expect("testbed boot");
    // Everything below goes through typed Api<K> handles over the unified
    // ApiClient — the same surface the remote CLI uses.
    let client = tb.client();
    let nodes: Api<NodeView> = Api::new(client.clone());
    let pods: Api<PodView> = Api::new(client.clone());
    let jobs: Api<WlmJobView> = Api::new(client); // default kind: TorqueJob
    println!(
        "testbed up: torque queues {:?}, {} kube node objects (incl. virtual node), red-box at {}\n",
        tb.pbs.queues().names(),
        nodes.list(&ListOptions::all()).map(|n| n.len()).unwrap_or(0),
        tb.socket().display()
    );

    println!("$ kubectl apply -f cow_job.yaml     # Fig. 3 manifest");
    tb.kubectl_apply(hpcorc::kube::yaml::COW_JOB_YAML).expect("apply");

    // Fig. 4: show each phase transition as a kubectl table.
    let mut last = String::new();
    loop {
        let obj = jobs.get_raw("cow").expect("get torquejob");
        let view = WlmJobView::from_object(&obj).expect("decode torquejob");
        let phase = view.status.clone();
        if phase != last && !phase.is_empty() {
            println!("\n$ kubectl get torquejob");
            println!("{:<6} {:<5} {:<10}", "NAME", "AGE", "STATUS");
            let now = jobs.server_time_s().unwrap_or(0.0);
            let age = fmt_age(Duration::from_secs_f64((now - obj.meta.creation_s).max(0.0)));
            println!("{:<6} {:<5} {:<10}", "cow", age, phase);
            if let Some(job_id) = &view.wlm_job_id {
                println!("  (Torque job id: {job_id} — also visible via qstat on the login node)");
            }
            last = phase.clone();
        }
        if hpcorc::operator::phase::terminal(&phase) {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }

    println!("\n$ cat $HOME/low.out                 # Fig. 5: staged by the results pod");
    print!("{}", tb.fs.read_string("$HOME/low.out").expect("low.out"));
    println!("\nresults copy in mount dir: $HOME/low.out -> {}", if tb.fs.exists("$HOME/low.out") { "present" } else { "missing" });

    println!("\npods involved (dummy + results + operator services):");
    for pod in pods.list(&ListOptions::all()).expect("list pods") {
        println!(
            "  {:<24} {:<10} node={}",
            pod.name,
            pod.phase.as_str(),
            pod.node_name.as_deref().unwrap_or("<none>")
        );
    }
    tb.stop();
    println!("\nquickstart OK");
}
