//! red-box client: multiplexed request/response and server streams over
//! one Unix socket, with lazy reconnect.
//!
//! Each connection runs a **demux reader thread**: responses route to the
//! caller that sent the matching request id, stream items route to the
//! per-stream channel registered when the stream was opened. Concurrent
//! calls from many threads therefore share one socket without
//! serializing behind each other — only the frame write itself is
//! mutex-guarded. An idle connection transmits nothing: there is no
//! polling anywhere in this client.
//!
//! Stream lifecycle: [`RedboxClient::open_stream`] sends a request and
//! returns the initial response body plus a [`ClientStream`] of
//! [`StreamMsg`]s. The stream ends when the server sends `StreamEnd`
//! (explicit [`StreamMsg::End`]) or the connection dies (the channel
//! disconnects with no `End` — stream loss). Dropping the `ClientStream`
//! unregisters it; the demux thread answers any later item with a cancel
//! frame so the server stops producing.

use super::proto::{read_frame, write_frame, Frame, Request, Response, END_CANCELLED};
use crate::encoding::Value;
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvError, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

/// One message of a client-side stream.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamMsg {
    /// One pushed item (seq continuity is checked by the demux thread).
    Item(Value),
    /// Explicit server end with its reason (`END_*` constants in
    /// [`super::proto`]). A stream whose channel disconnects *without*
    /// an `End` lost its connection instead.
    End(String),
}

struct StreamRoute {
    tx: Sender<StreamMsg>,
    next_seq: u64,
}

/// Demux routing state. `dead` is flipped under the same lock that guards
/// the maps, so registrations cannot race the reader thread's final
/// drain: once dead, nothing new registers and everything in flight has
/// been failed.
struct Routes {
    dead: bool,
    pending: HashMap<u64, Sender<Response>>,
    streams: HashMap<u64, StreamRoute>,
}

struct Conn {
    writer: Arc<Mutex<UnixStream>>,
    routes: Arc<Mutex<Routes>>,
    /// Socket handle used to unblock the reader thread when this
    /// connection is abandoned (reconnect or client drop).
    control: UnixStream,
}

impl Drop for Conn {
    fn drop(&mut self) {
        let _ = self.control.shutdown(std::net::Shutdown::Both);
    }
}

impl Conn {
    /// Register a pending-response slot and send the request. `Err` means
    /// this connection is unusable (the caller reconnects and retries).
    fn send_request(&self, req: &Request) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        {
            let mut r = self.routes.lock().unwrap();
            if r.dead {
                return Err(Error::rpc("connection closed"));
            }
            r.pending.insert(req.id, tx);
        }
        let wrote = {
            let mut w = self.writer.lock().unwrap();
            write_frame(&mut *w, &req.encode())
        };
        if let Err(e) = wrote {
            self.routes.lock().unwrap().pending.remove(&req.id);
            return Err(e);
        }
        Ok(rx)
    }

    fn register_stream(&self, id: u64) -> Result<Receiver<StreamMsg>> {
        let (tx, rx) = channel();
        let mut r = self.routes.lock().unwrap();
        if r.dead {
            return Err(Error::rpc("connection closed"));
        }
        r.streams.insert(id, StreamRoute { tx, next_seq: 0 });
        Ok(rx)
    }

    fn drop_stream(&self, id: u64) {
        self.routes.lock().unwrap().streams.remove(&id);
    }
}

/// The demux loop: routes every incoming frame by id, then fails all
/// in-flight work when the connection ends.
fn reader_loop(
    mut stream: UnixStream,
    writer: Arc<Mutex<UnixStream>>,
    routes: Arc<Mutex<Routes>>,
) {
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(Some(v)) => v,
            Ok(None) | Err(_) => break,
        };
        let frame = match Frame::decode(&frame) {
            Ok(f) => f,
            Err(_) => break, // protocol corruption: poison the connection
        };
        match frame {
            Frame::Response(resp) => {
                let tx = routes.lock().unwrap().pending.remove(&resp.id);
                match tx {
                    Some(tx) => {
                        let _ = tx.send(resp);
                    }
                    // id 0 = the server could not parse one of our frames;
                    // any other unknown id means demux state is corrupt.
                    // Either way the connection cannot be trusted.
                    None => break,
                }
            }
            Frame::StreamItem { id, seq, body } => {
                let mut cancel = false;
                {
                    let mut r = routes.lock().unwrap();
                    match r.streams.get_mut(&id) {
                        Some(route) => {
                            if seq != route.next_seq {
                                // A gap means lost items: end the stream
                                // so the consumer relists instead of
                                // trusting a hole.
                                r.streams.remove(&id);
                                cancel = true;
                            } else {
                                route.next_seq += 1;
                                if route.tx.send(StreamMsg::Item(body)).is_err() {
                                    // Consumer went away.
                                    r.streams.remove(&id);
                                    cancel = true;
                                }
                            }
                        }
                        // Item for a stream we dropped: re-signal cancel.
                        None => cancel = true,
                    }
                }
                if cancel {
                    // Off the reader thread: the reader must never block
                    // on the writer mutex — if both directions' socket
                    // buffers filled, a reader waiting to write while
                    // writers wait for the peer to read would deadlock
                    // the connection. Cancels are rare (stream teardown
                    // only), so a short-lived thread is fine.
                    let writer = writer.clone();
                    crate::rt::spawn_named("redbox-cancel", move || {
                        let end = Frame::StreamEnd { id, reason: END_CANCELLED.into() };
                        let mut w = writer.lock().unwrap();
                        let _ = write_frame(&mut *w, &end.encode());
                    });
                }
            }
            Frame::StreamEnd { id, reason } => {
                let route = routes.lock().unwrap().streams.remove(&id);
                if let Some(route) = route {
                    let _ = route.tx.send(StreamMsg::End(reason));
                }
            }
            Frame::Request(_) => break, // servers do not send requests
        }
    }
    // Connection over: dropping the senders fails every pending call
    // (disconnect) and ends every stream without an `End` (stream loss).
    let mut r = routes.lock().unwrap();
    r.dead = true;
    r.pending.clear();
    r.streams.clear();
}

/// A live server stream. Receive with [`ClientStream::recv`] /
/// [`ClientStream::recv_timeout`]; drop to unsubscribe (the server is
/// told to stop on its next push).
pub struct ClientStream {
    rx: Receiver<StreamMsg>,
    id: u64,
    conn: Weak<Conn>,
}

impl ClientStream {
    pub fn recv(&self) -> std::result::Result<StreamMsg, RecvError> {
        self.rx.recv()
    }

    pub fn recv_timeout(
        &self,
        d: Duration,
    ) -> std::result::Result<StreamMsg, RecvTimeoutError> {
        self.rx.recv_timeout(d)
    }

    pub fn try_recv(&self) -> std::result::Result<StreamMsg, TryRecvError> {
        self.rx.try_recv()
    }
}

impl Drop for ClientStream {
    fn drop(&mut self) {
        let Some(conn) = self.conn.upgrade() else { return };
        let was_live = conn.routes.lock().unwrap().streams.remove(&self.id).is_some();
        if was_live {
            // The server does not know we stopped listening until told:
            // without this cancel, an *idle* stream's producer thread
            // (and its store watcher) would live until the connection
            // closes — there is no next item to bounce a cancel off.
            let end = Frame::StreamEnd { id: self.id, reason: END_CANCELLED.into() };
            let mut w = conn.writer.lock().unwrap();
            let _ = write_frame(&mut *w, &end.encode());
        }
    }
}

pub struct RedboxClient {
    path: PathBuf,
    conn: Mutex<Option<Arc<Conn>>>,
    next_id: AtomicU64,
}

impl RedboxClient {
    /// Connect now; fails fast if the server socket is absent.
    pub fn connect(path: impl AsRef<Path>) -> Result<RedboxClient> {
        let path = path.as_ref().to_path_buf();
        let conn = Self::new_conn(&path)?;
        Ok(RedboxClient {
            path,
            conn: Mutex::new(Some(conn)),
            next_id: AtomicU64::new(1),
        })
    }

    /// Connect with retry — used at testbed boot where daemon start order
    /// is not guaranteed.
    pub fn connect_retry(path: impl AsRef<Path>, timeout: Duration) -> Result<RedboxClient> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            match Self::connect(path.as_ref()) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if std::time::Instant::now() >= deadline {
                        return Err(e);
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        }
    }

    fn new_conn(path: &Path) -> Result<Arc<Conn>> {
        let stream = UnixStream::connect(path)
            .map_err(|e| Error::rpc(format!("connect {}: {e}", path.display())))?;
        let reader = stream.try_clone()?;
        let control = stream.try_clone()?;
        let writer = Arc::new(Mutex::new(stream));
        let routes = Arc::new(Mutex::new(Routes {
            dead: false,
            pending: HashMap::new(),
            streams: HashMap::new(),
        }));
        let (w2, r2) = (writer.clone(), routes.clone());
        crate::rt::spawn_named("redbox-demux", move || reader_loop(reader, w2, r2));
        Ok(Arc::new(Conn { writer, routes, control }))
    }

    /// The live connection, reconnecting lazily if the previous one died.
    fn conn(&self) -> Result<Arc<Conn>> {
        let mut guard = self.conn.lock().unwrap();
        if let Some(c) = guard.as_ref() {
            if !c.routes.lock().unwrap().dead {
                return Ok(c.clone());
            }
        }
        let c = Self::new_conn(&self.path)?;
        *guard = Some(c.clone());
        Ok(c)
    }

    /// Drop the current connection so the next call reconnects. Threads
    /// still using the old connection finish against it; its reader
    /// unblocks when the last handle drops.
    fn invalidate(&self) {
        *self.conn.lock().unwrap() = None;
    }

    /// Issue `Service/Method` with a JSON body; returns the response body.
    /// One transparent reconnect+retry on transport failure (the server
    /// may have restarted — red-box "future work: more stable
    /// deployments"). Method-level errors never retry.
    pub fn call(&self, method: &str, body: Value) -> Result<Value> {
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            method: method.to_string(),
            body,
            trace: crate::obs::current().map(|c| c.to_wire()),
            actor: crate::obs::current_actor(),
        };
        match self.round_trip(&req) {
            Ok(resp) => resp.into_result(),
            Err(first) => {
                self.invalidate();
                match self.round_trip(&req) {
                    Ok(resp) => resp.into_result(),
                    Err(_) => Err(first),
                }
            }
        }
    }

    fn round_trip(&self, req: &Request) -> Result<Response> {
        let conn = self.conn()?;
        let rx = conn.send_request(req)?;
        rx.recv().map_err(|_| Error::rpc("server closed connection"))
    }

    /// Open a server stream: send `method` and return the initial
    /// response body plus the item stream. The stream route registers
    /// *before* the request goes out, so no pushed item can be missed.
    /// Reconnects+retries once on transport failure (safe: nothing has
    /// streamed until the server accepts); a server that answers the
    /// method with an error fails this call without a retry.
    pub fn open_stream(&self, method: &str, body: Value) -> Result<(Value, ClientStream)> {
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            method: method.to_string(),
            body,
            trace: crate::obs::current().map(|c| c.to_wire()),
            actor: crate::obs::current_actor(),
        };
        let (conn, resp, stream) = match self.try_open(&req) {
            Ok(out) => out,
            Err(first) => {
                self.invalidate();
                self.try_open(&req).map_err(|_| first)?
            }
        };
        match resp.into_result() {
            Ok(initial) => Ok((initial, stream)),
            Err(e) => {
                conn.drop_stream(req.id);
                Err(e)
            }
        }
    }

    fn try_open(&self, req: &Request) -> Result<(Arc<Conn>, Response, ClientStream)> {
        let conn = self.conn()?;
        let rx = conn.register_stream(req.id)?;
        // From here on, an early return drops `stream`, whose Drop impl
        // unregisters the route — no leak on any failure path.
        let stream = ClientStream { rx, id: req.id, conn: Arc::downgrade(&conn) };
        let rrx = conn.send_request(req)?;
        let resp = rrx
            .recv()
            .map_err(|_| Error::rpc("server closed connection"))?;
        Ok((conn, resp, stream))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Metrics;
    use crate::redbox::proto::{END_COMPLETE, END_GONE};
    use crate::redbox::server::{FnService, RedboxServer, Reply, Service};
    use crate::rt::Shutdown;
    use std::sync::Arc;

    fn sock(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hpcorc-cli-{tag}-{}.sock", std::process::id()))
    }

    #[test]
    fn connect_fails_without_server() {
        assert!(RedboxClient::connect("/tmp/does-not-exist-hpcorc.sock").is_err());
    }

    #[test]
    fn reconnects_after_server_restart() {
        let path = sock("restart");
        let sd1 = Shutdown::new();
        let mut srv1 = RedboxServer::start(&path, sd1.clone(), Metrics::new()).unwrap();
        srv1.register("s.S", Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Int(1)))));
        let client = RedboxClient::connect(&path).unwrap();
        assert_eq!(client.call("s.S/m", Value::Null).unwrap(), Value::Int(1));
        srv1.stop();
        // Server gone: a fresh server comes up on the same socket.
        let sd2 = Shutdown::new();
        let mut srv2 = RedboxServer::start(&path, sd2.clone(), Metrics::new()).unwrap();
        srv2.register("s.S", Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Int(2)))));
        // The old connection is dead; call() reconnects transparently.
        assert_eq!(client.call("s.S/m", Value::Null).unwrap(), Value::Int(2));
        srv2.stop();
    }

    #[test]
    fn connect_retry_waits_for_server() {
        let path = sock("retry");
        let p2 = path.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            let sd = Shutdown::new();
            let mut srv = RedboxServer::start(&p2, sd, Metrics::new()).unwrap();
            srv.register("s.S", Arc::new(FnService(|_: &str, _: &Value| Ok(Value::Null))));
            std::thread::sleep(Duration::from_millis(200));
            srv.stop();
        });
        let c = RedboxClient::connect_retry(&path, Duration::from_secs(5)).unwrap();
        assert!(c.call("s.S/m", Value::Null).is_ok());
        t.join().unwrap();
    }

    /// A test service with one unary and one streaming method: `Count`
    /// streams `n` integers then ends with the reason in the body.
    struct CountService;

    impl Service for CountService {
        fn call(&self, method: &str, body: &Value) -> Result<Value> {
            match method {
                "Echo" => Ok(body.clone()),
                other => Err(Error::rpc(format!("no method `{other}`"))),
            }
        }

        fn call_full(&self, method: &str, body: &Value) -> Result<Reply> {
            if method != "Count" {
                return self.call(method, body).map(Reply::Unary);
            }
            let n = body.opt_int("n").unwrap_or(0);
            let reason = body
                .opt_str("reason")
                .unwrap_or(END_COMPLETE)
                .to_string();
            Ok(Reply::stream(Value::map().with("accepted", true), move |mut sink| {
                for i in 0..n {
                    if !sink.item(Value::Int(i)) {
                        return;
                    }
                }
                sink.end(&reason);
            }))
        }
    }

    #[test]
    fn server_stream_items_then_end() {
        let sd = Shutdown::new();
        let mut srv = RedboxServer::start(sock("stream"), sd, Metrics::new()).unwrap();
        srv.register("t.Count", Arc::new(CountService));
        let client = RedboxClient::connect(srv.path()).unwrap();
        let (initial, stream) = client
            .open_stream("t.Count/Count", Value::map().with("n", 3i64))
            .unwrap();
        assert_eq!(initial.opt_bool("accepted"), Some(true));
        let mut got = Vec::new();
        loop {
            match stream.recv_timeout(Duration::from_secs(5)).unwrap() {
                StreamMsg::Item(v) => got.push(v),
                StreamMsg::End(reason) => {
                    assert_eq!(reason, END_COMPLETE);
                    break;
                }
            }
        }
        assert_eq!(got, vec![Value::Int(0), Value::Int(1), Value::Int(2)]);
        // The channel is cleanly closed after End.
        assert!(matches!(stream.try_recv(), Err(TryRecvError::Disconnected)));
        assert_eq!(srv.metrics().counter_value("redbox.streams"), 1);
        srv.stop();
    }

    #[test]
    fn stream_end_reason_travels() {
        let sd = Shutdown::new();
        let mut srv = RedboxServer::start(sock("gone"), sd, Metrics::new()).unwrap();
        srv.register("t.Count", Arc::new(CountService));
        let client = RedboxClient::connect(srv.path()).unwrap();
        let (_, stream) = client
            .open_stream(
                "t.Count/Count",
                Value::map().with("n", 0i64).with("reason", END_GONE),
            )
            .unwrap();
        match stream.recv_timeout(Duration::from_secs(5)).unwrap() {
            StreamMsg::End(reason) => assert_eq!(reason, END_GONE),
            other => panic!("expected gone end, got {other:?}"),
        }
        srv.stop();
    }

    #[test]
    fn unary_calls_interleave_with_a_live_stream() {
        // The multiplexing contract: one connection carries a live stream
        // and concurrent request/response traffic at the same time.
        let sd = Shutdown::new();
        let mut srv = RedboxServer::start(sock("mux"), sd, Metrics::new()).unwrap();
        srv.register("t.Count", Arc::new(CountService));
        let client = RedboxClient::connect(srv.path()).unwrap();
        let (_, stream) = client
            .open_stream("t.Count/Count", Value::map().with("n", 50i64))
            .unwrap();
        // Unary traffic on the same socket while items are in flight.
        for i in 0..10i64 {
            assert_eq!(client.call("t.Count/Echo", Value::Int(i)).unwrap(), Value::Int(i));
        }
        let mut items = 0;
        loop {
            match stream.recv_timeout(Duration::from_secs(5)).unwrap() {
                StreamMsg::Item(_) => items += 1,
                StreamMsg::End(_) => break,
            }
        }
        assert_eq!(items, 50);
        srv.stop();
    }

    #[test]
    fn method_error_on_stream_open_is_typed_not_retried() {
        let sd = Shutdown::new();
        let mut srv = RedboxServer::start(sock("serr"), sd, Metrics::new()).unwrap();
        srv.register(
            "t.Err",
            Arc::new(FnService(|_: &str, _: &Value| -> Result<Value> {
                Err(Error::not_found("Pod", "ghost"))
            })),
        );
        let client = RedboxClient::connect(srv.path()).unwrap();
        let err = client.open_stream("t.Err/X", Value::Null).unwrap_err();
        assert!(err.is_not_found(), "got {err}");
        srv.stop();
    }

    #[test]
    fn server_restart_ends_stream_without_end_marker() {
        let path = sock("sloss");
        let sd = Shutdown::new();
        let mut srv = RedboxServer::start(&path, sd, Metrics::new()).unwrap();
        // A stream that never completes on its own.
        srv.register(
            "t.Hang",
            Arc::new(HangService),
        );
        let client = RedboxClient::connect(&path).unwrap();
        let (_, stream) = client.open_stream("t.Hang/Watch", Value::Null).unwrap();
        srv.stop();
        // Stream loss = disconnect with no StreamMsg::End.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match stream.recv_timeout(Duration::from_millis(50)) {
                Ok(StreamMsg::End(r)) => panic!("lost stream must not see End({r})"),
                Ok(StreamMsg::Item(_)) => {}
                Err(RecvTimeoutError::Timeout) => {
                    assert!(std::time::Instant::now() < deadline, "stream never ended");
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }

    /// Streams nothing and waits for cancellation.
    struct HangService;

    impl Service for HangService {
        fn call(&self, _: &str, _: &Value) -> Result<Value> {
            Err(Error::rpc("unary not supported"))
        }
        fn call_full(&self, _: &str, _: &Value) -> Result<Reply> {
            Ok(Reply::stream(Value::map(), |sink| {
                while !sink.wait_cancelled(Duration::from_millis(10)) {}
            }))
        }
    }
}
