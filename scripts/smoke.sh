#!/usr/bin/env bash
# End-to-end CLI smoke: drive the release binary the way a user would —
# trace generation, the simulator's elastic and kueue-quota paths, and a
# live testbed exercised through the kubectl table paths over the red-box
# socket. Run by the CI `smoke` job; runs locally too:
#
#   cargo build --release --manifest-path rust/Cargo.toml
#   scripts/smoke.sh rust/target/release/hpcorc
set -euo pipefail

HPCORC="${1:-rust/target/release/hpcorc}"
command -v "$HPCORC" >/dev/null || [ -x "$HPCORC" ] || {
  echo "smoke: binary not found: $HPCORC" >&2
  exit 1
}
WORK="$(mktemp -d)"
SOCK="$WORK/redbox.sock"
UP_PID=""
cleanup() {
  [ -n "$UP_PID" ] && kill "$UP_PID" 2>/dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== trace gen (diurnal) =="
"$HPCORC" trace gen --kind diurnal --jobs 80 --out "$WORK/diurnal.json"
test -s "$WORK/diurnal.json"

echo "== sim: static vs elastic on the diurnal trace =="
"$HPCORC" sim --trace "$WORK/diurnal.json" --policy easy --nodes 8
"$HPCORC" sim --trace "$WORK/diurnal.json" --policy easy \
  --elastic-max 8 --elastic-min 1 --provision-delay 30 --idle-window 300

echo "== sim: kueue quota admission over a generated tenants trace =="
"$HPCORC" sim --kind tenants --jobs 60 --policy easy --quota-nodes 4 --cohort

echo "== sim: flash-crowd burst trace through the indexed scheduler (PR 9) =="
"$HPCORC" trace gen --kind bursty --jobs 100 --out "$WORK/bursty.json"
test -s "$WORK/bursty.json"
"$HPCORC" sim --trace "$WORK/bursty.json" --policy easy --nodes 8

echo "== testbed up + kubectl table paths over the socket =="
"$HPCORC" up --socket "$SOCK" --run-for 120 --audit-log "$WORK/audit.jsonl" >"$WORK/up.log" 2>&1 &
UP_PID=$!
for _ in $(seq 1 100); do
  [ -S "$SOCK" ] && break
  sleep 0.1
done
if ! [ -S "$SOCK" ]; then
  echo "smoke: red-box socket never appeared" >&2
  cat "$WORK/up.log" >&2
  exit 1
fi

cat >"$WORK/cq.yaml" <<'EOF'
apiVersion: kueue.x-k8s.io/v1beta1
kind: ClusterQueue
metadata:
  name: smoke-cq
spec:
  quota:
    nodes: 4
EOF
"$HPCORC" kubectl apply -f "$WORK/cq.yaml" --socket "$SOCK"
"$HPCORC" kubectl get cq --socket "$SOCK" | tee "$WORK/cq.out"
grep -q smoke-cq "$WORK/cq.out"

cat >"$WORK/tj.yaml" <<'EOF'
apiVersion: wlm.sylabs.io/v1alpha1
kind: TorqueJob
metadata:
  name: smoke-cow
spec:
  batch: |
    #!/bin/sh
    #PBS -l walltime=00:30:00
    #PBS -l nodes=1
    #PBS -e $HOME/smoke.err
    #PBS -o $HOME/smoke.out
    singularity run lolcow_latest.sif
  results:
    from: $HOME/smoke.out
  mount:
    name: data
    hostPath:
      path: $HOME/
      type: DirectoryOrCreate
EOF
"$HPCORC" kubectl apply -f "$WORK/tj.yaml" --socket "$SOCK"
for _ in $(seq 1 150); do
  "$HPCORC" kubectl get tj --socket "$SOCK" >"$WORK/tj.out"
  grep -Eq 'completed|failed' "$WORK/tj.out" && break
  sleep 0.2
done
cat "$WORK/tj.out"
grep -q smoke-cow "$WORK/tj.out"
grep -q completed "$WORK/tj.out"

"$HPCORC" kubectl get pods --socket "$SOCK" >/dev/null
"$HPCORC" kubectl get nodes --socket "$SOCK" >/dev/null

echo "== observability plane: remote metrics scrape + trace timeline =="
# Prometheus text exposition over the live socket (PR 7): the RPC-layer
# and store-commit histograms must be present in well-formed families.
"$HPCORC" metrics --socket "$SOCK" --prom >"$WORK/metrics.prom"
grep -q '^# TYPE redbox_requests counter' "$WORK/metrics.prom"
grep -q '^# TYPE kube_store_commit_ns histogram' "$WORK/metrics.prom"
grep -q 'kube_store_commit_ns_bucket{le="+Inf"}' "$WORK/metrics.prom"
"$HPCORC" metrics --socket "$SOCK" --json >"$WORK/metrics.json"
grep -q '"counters"' "$WORK/metrics.json"
# Lifecycle timeline reconstructed from an object's originating trace
# annotation. Use a freshly-applied object so its spans are still in the
# daemon's (bounded) span ring when we ask.
cat >"$WORK/trace-cq.yaml" <<'EOF'
apiVersion: kueue.x-k8s.io/v1beta1
kind: ClusterQueue
metadata:
  name: smoke-trace-cq
spec:
  quota:
    nodes: 1
EOF
"$HPCORC" kubectl apply -f "$WORK/trace-cq.yaml" --socket "$SOCK"
"$HPCORC" trace cq/smoke-trace-cq --socket "$SOCK" | tee "$WORK/trace.out"
grep -q '^trace ' "$WORK/trace.out"
grep -q 'apiserver' "$WORK/trace.out"
# And the Chrome trace-event export parses as JSON (Perfetto-loadable).
"$HPCORC" trace cq/smoke-trace-cq --socket "$SOCK" --json >"$WORK/trace.json"
python3 -c "import json,sys; json.load(open('$WORK/trace.json'))" 2>/dev/null \
  || node -e "JSON.parse(require('fs').readFileSync('$WORK/trace.json'))" 2>/dev/null \
  || grep -q '^\[' "$WORK/trace.json"

echo "== cluster events + audit trail (PR 8) =="
# A queued pod drives the full event fan: kueue admits it, the scheduler
# binds it, a kubelet pulls + starts it — four events, three components.
cat >"$WORK/ev.yaml" <<'EOF'
apiVersion: kueue.x-k8s.io/v1beta1
kind: LocalQueue
metadata:
  name: smoke-team
spec:
  clusterQueue: smoke-cq
---
kind: Pod
metadata:
  name: smoke-ev-pod
  labels:
    kueue.x-k8s.io/queue-name: smoke-team
spec:
  containers:
    - name: main
      image: lolcow_latest.sif
      resources:
        requests:
          cpu: 100m
EOF
"$HPCORC" kubectl apply -f "$WORK/ev.yaml" --socket "$SOCK"
for _ in $(seq 1 150); do
  "$HPCORC" kubectl get events --socket "$SOCK" >"$WORK/events.out"
  grep -q Started "$WORK/events.out" && break
  sleep 0.2
done
cat "$WORK/events.out"
for reason in Admitted Scheduled Pulled Started; do
  grep -q "$reason" "$WORK/events.out"
done
# `kubectl describe` interleaves the object, its events (>=4, from >=3
# components), and the trace timeline — one command, whole lifecycle.
"$HPCORC" kubectl describe pod/smoke-ev-pod --socket "$SOCK" | tee "$WORK/describe.out"
grep -q '^Events:' "$WORK/describe.out"
for reason in Admitted Scheduled Pulled Started; do
  grep -q "$reason" "$WORK/describe.out"
done
for component in kueue kube-scheduler kubelet; do
  grep -q "$component" "$WORK/describe.out"
done
grep -q '^trace ' "$WORK/describe.out"
# The audit trail attributes the CLI's mutating requests, and its trace
# id for the pod create matches the describe timeline's.
"$HPCORC" audit --socket "$SOCK" --kind po >"$WORK/audit.out"
cat "$WORK/audit.out"
grep -Eq 'create[[:space:]]+Pod[[:space:]]+smoke-ev-pod[[:space:]]+kubectl[[:space:]]+ok' "$WORK/audit.out"
TRACE=$(grep -E 'create[[:space:]]+Pod[[:space:]]+smoke-ev-pod' "$WORK/audit.out" | grep -oE '[0-9a-f]{16}$' | head -1)
test -n "$TRACE"
grep -q "$TRACE" "$WORK/describe.out"
# The --audit-log file sink captured the same records as JSON lines.
grep -q '"verb"' "$WORK/audit.jsonl"
grep -q 'smoke-ev-pod' "$WORK/audit.jsonl"
# Labelled metric families (PR 8): a fresh scrape exposes real {k="v"}
# pairs for the API verbs and the event-emission counters.
"$HPCORC" metrics --socket "$SOCK" --prom >"$WORK/metrics2.prom"
grep -q 'kube_api_create{gvk="events"}' "$WORK/metrics2.prom"
grep -q 'kube_events_emitted{reason="Scheduled"}' "$WORK/metrics2.prom"
grep -q '^# TYPE kube_api_audit_records counter' "$WORK/metrics2.prom"

echo "== scheduler burst: batched binds visible end-to-end (PR 9) =="
# 16 pods land at once; the daemon scheduler drains them through the
# fit/score index and commits the binds batched. Success is observable
# from outside: the outcome-labelled bound counter advances by the whole
# burst, and the PR 9 histogram/gauge families are in the scrape.
sched_bound() {
  "$HPCORC" metrics --socket "$SOCK" --prom 2>/dev/null \
    | awk '$1 == "kube_sched_bound{outcome=\"ok\"}" { n = $2 } END { print n + 0 }'
}
BOUND0=$(sched_bound)
for i in $(seq 1 16); do
  cat >"$WORK/burst-pod.yaml" <<EOF
kind: Pod
metadata:
  name: smoke-burst-$i
spec:
  containers:
    - name: main
      image: lolcow_latest.sif
      resources:
        requests:
          cpu: 50m
EOF
  "$HPCORC" kubectl apply -f "$WORK/burst-pod.yaml" --socket "$SOCK"
done
for _ in $(seq 1 150); do
  [ "$(( $(sched_bound) - BOUND0 ))" -ge 16 ] && break
  sleep 0.2
done
BOUND=$(sched_bound)
if [ "$((BOUND - BOUND0))" -lt 16 ]; then
  echo "smoke: burst never fully bound (bound=$BOUND baseline=$BOUND0)" >&2
  exit 1
fi
"$HPCORC" metrics --socket "$SOCK" --prom >"$WORK/metrics3.prom"
grep -q '^# TYPE kube_sched_bind_batch_ns histogram' "$WORK/metrics3.prom"
grep -q '^# TYPE kube_sched_pending gauge' "$WORK/metrics3.prom"
grep -q 'kube_sched_bound{outcome="ok"}' "$WORK/metrics3.prom"

kill "$UP_PID" 2>/dev/null || true
wait "$UP_PID" 2>/dev/null || true
UP_PID=""

echo "== durable restart: same --wal-dir boots back to identical state =="
# AGE is wall-clock and legitimately advances across the restart; every
# other column (names, statuses, bindings, queue counts) must come back
# byte-identical from the WAL.
strip_age() { awk '{ $2 = "-"; print }'; }
snapshot() {
  {
    "$HPCORC" kubectl get cq --socket "$1"
    "$HPCORC" kubectl get tj --socket "$1" | strip_age
    "$HPCORC" kubectl get nodes --socket "$1" | strip_age
    "$HPCORC" kubectl get pods --socket "$1" | strip_age
  } >"$2"
}
WAL="$WORK/wal"
SOCK2="$WORK/redbox2.sock"
"$HPCORC" up --socket "$SOCK2" --run-for 120 --wal-dir "$WAL" >"$WORK/up-wal1.log" 2>&1 &
UP_PID=$!
for _ in $(seq 1 100); do
  [ -S "$SOCK2" ] && break
  sleep 0.1
done
if ! [ -S "$SOCK2" ]; then
  echo "smoke: WAL testbed socket never appeared" >&2
  cat "$WORK/up-wal1.log" >&2
  exit 1
fi
"$HPCORC" kubectl apply -f "$WORK/cq.yaml" --socket "$SOCK2"
"$HPCORC" kubectl apply -f "$WORK/tj.yaml" --socket "$SOCK2"
for _ in $(seq 1 150); do
  "$HPCORC" kubectl get tj --socket "$SOCK2" >"$WORK/tj2.out"
  grep -Eq 'completed|failed' "$WORK/tj2.out" && break
  sleep 0.2
done
grep -q completed "$WORK/tj2.out"
snapshot "$SOCK2" "$WORK/golden.txt"
grep -q smoke-cow "$WORK/golden.txt"
grep -q smoke-cq "$WORK/golden.txt"
kill "$UP_PID" 2>/dev/null || true
wait "$UP_PID" 2>/dev/null || true

# Reboot on the same WAL dir: no re-applies — everything must recover.
SOCK3="$WORK/redbox3.sock"
"$HPCORC" up --socket "$SOCK3" --run-for 120 --wal-dir "$WAL" >"$WORK/up-wal2.log" 2>&1 &
UP_PID=$!
for _ in $(seq 1 100); do
  [ -S "$SOCK3" ] && break
  sleep 0.1
done
if ! [ -S "$SOCK3" ]; then
  echo "smoke: recovered testbed socket never appeared" >&2
  cat "$WORK/up-wal2.log" >&2
  exit 1
fi
for i in $(seq 1 20); do
  snapshot "$SOCK3" "$WORK/recovered.txt"
  if diff -u "$WORK/golden.txt" "$WORK/recovered.txt"; then
    break
  fi
  if [ "$i" = 20 ]; then
    echo "smoke: recovered state diverges from golden transcript" >&2
    exit 1
  fi
  sleep 0.5
done

kill "$UP_PID" 2>/dev/null || true
wait "$UP_PID" 2>/dev/null || true
UP_PID=""

echo "== chaos: seeded kill-and-recover under injected faults (PR 10) =="
# The chaos verb boots its own testbeds (no socket needed here) and exits
# nonzero if the faulted run's final transcript diverges from the clean
# golden. redbox-drop covers the connection-fault path; apiserver-restart
# covers the kill-and-recover WAL leg from *inside* the harness, with the
# golden-transcript diff done by the scenario itself.
"$HPCORC" chaos --scenario redbox-drop --seed 7
"$HPCORC" chaos --scenario apiserver-restart --seed 7 | tee "$WORK/chaos-restart.out"
grep -q CONVERGED "$WORK/chaos-restart.out"
# Same seed, same verdicts: fault counts vary with poll timing, but the
# converged flags must be byte-identical across reruns.
"$HPCORC" chaos --scenario redbox-drop --seed 42 --json >"$WORK/chaos-a.json"
"$HPCORC" chaos --scenario redbox-drop --seed 42 --json >"$WORK/chaos-b.json"
diff <(grep -o '"converged":[a-z]*' "$WORK/chaos-a.json") \
     <(grep -o '"converged":[a-z]*' "$WORK/chaos-b.json")

echo "smoke OK"
