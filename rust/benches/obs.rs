//! Observability-layer overhead (PR 7): what tracing and metric
//! exposition cost the hot paths.
//!
//! - `obs/span_record`: open+close one span (the per-operation cost every
//!   instrumented call site pays while tracing is on).
//! - `obs/span_disabled`: the same call with tracing off — one atomic
//!   load; this is the price the whole fleet pays when nobody is looking.
//! - `obs/nested_span_x8`: an 8-deep child chain (a worst-case causal
//!   tree step, e.g. CLI → redbox → apiserver → store).
//! - `obs/span_sampled_out`: a root span under 1-in-N sampling that loses
//!   the coin flip (`HPCORC_TRACE_SAMPLE`) — guard + one modulo, no ring
//!   write. Asserted cheap below, same as the disabled path.
//! - `obs/prom_render_10k`: render a 10k-metric registry to Prometheus
//!   text (one full scrape).
//! - `obs/prom_render_10k_labelled`: same series count, but spread over
//!   labelled families (PR 8) — the canonical-key split/group cost.
//! - `obs/json_snapshot_10k`: same registry as the structured snapshot.
//! - `obs/event_record_coalesced`: `EventRecorder::event` for a repeated
//!   `(object, reason)` — the hot path every control loop pays per cycle
//!   once the first event object exists (a count-bump `update_status`).
//!
//! Prints `{"bench":...}` JSON rows for the CI perf trajectory.

use hpcorc::bench::{header, Bench};
use hpcorc::cluster::{Metrics, Resources};
use hpcorc::obs;

fn main() {
    println!("== observability overhead (PR 7) ==");
    println!("{}", header());
    let mut rows = Vec::new();

    // Per-span record cost, tracing on.
    obs::set_enabled(true);
    obs::clear();
    rows.push(Bench::new("obs/span_record").warmup(1000).iters(20_000).run(|| {
        let _g = obs::span("bench", "op");
    }));

    // Disabled path: the guard must be near-free.
    obs::set_enabled(false);
    rows.push(Bench::new("obs/span_disabled").warmup(1000).iters(20_000).run(|| {
        let _g = obs::span("bench", "op");
    }));
    obs::set_enabled(true);

    // Sampled-out path (PR 8): tracing on, but the root span loses the
    // 1-in-N coin flip — spans open but are dropped at close. The cost a
    // production fleet pays per un-sampled operation.
    obs::set_trace_sample(1 << 30);
    rows.push(Bench::new("obs/span_sampled_out").warmup(1000).iters(20_000).run(|| {
        let _g = obs::span("bench", "op");
    }));
    obs::set_trace_sample(1);

    // Nested chain: stack push/pop + parent linkage, 8 levels.
    rows.push(Bench::new("obs/nested_span_x8").warmup(100).iters(5_000).run(|| {
        let _a = obs::span("bench", "l0");
        let _b = obs::span("bench", "l1");
        let _c = obs::span("bench", "l2");
        let _d = obs::span("bench", "l3");
        let _e = obs::span("bench", "l4");
        let _f = obs::span("bench", "l5");
        let _g = obs::span("bench", "l6");
        let _h = obs::span("bench", "l7");
    }));

    // A populated registry: 10k metrics split across the three families,
    // histograms fed enough samples to spread over buckets.
    let m = Metrics::new();
    for i in 0..6000u64 {
        m.add(&format!("bench.counter.{i:04}"), i);
    }
    for i in 0..2000i64 {
        m.set_gauge(&format!("bench.gauge.{i:04}"), i - 1000);
    }
    for i in 0..2000u64 {
        let name = format!("bench.hist.{i:04}");
        for s in [100, 5_000, 250_000, 10_000_000] {
            m.observe(&name, s + i);
        }
    }
    rows.push(Bench::new("obs/prom_render_10k").warmup(2).iters(20).run(|| {
        std::hint::black_box(obs::render_prom(&m));
    }));
    rows.push(Bench::new("obs/json_snapshot_10k").warmup(2).iters(20).run(|| {
        std::hint::black_box(obs::render_json(&m));
    }));

    // 10k series spread over labelled families (PR 8): 100 families x 100
    // label sets each — the exposition pays the canonical-key split and
    // per-family grouping instead of a flat walk.
    let lm = Metrics::new();
    for f in 0..100u64 {
        for l in 0..100u64 {
            lm.inc_with(&format!("bench.labelled.{f:02}"), &[("shard", format!("s{l:03}").as_str())]);
        }
    }
    rows.push(Bench::new("obs/prom_render_10k_labelled").warmup(2).iters(20).run(|| {
        std::hint::black_box(obs::render_prom(&lm));
    }));

    // Event-record hot path (PR 8): repeated (object, reason) against an
    // in-process ApiServer — after the first create, every call is the
    // coalesced count-bump (`update_status` + dedup-map hit).
    let api = hpcorc::kube::ApiServer::new(Metrics::new());
    let pod = hpcorc::kube::PodView::build("bench-pod", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
    let pod = api.create(pod).unwrap();
    let rec = hpcorc::kube::EventRecorder::new("bench", Metrics::new());
    let client = api.client();
    rows.push(Bench::new("obs/event_record_coalesced").warmup(100).iters(5_000).run(|| {
        rec.event(
            client.as_ref(),
            &pod,
            hpcorc::kube::EVENT_NORMAL,
            "BenchTick",
            "benchmark event",
        )
        .unwrap();
    }));

    println!();
    for s in &rows {
        println!("{}", s.json());
    }

    // Guardrails (PR 8, asserted): the paths a fleet pays when nobody is
    // looking must stay far cheaper than recording. A regression here
    // means someone put work in front of the enabled()/sampled() checks.
    // Margins are generous (5x + 200ns slack) to stay CI-stable.
    let record = rows[0].mean_ns;
    let disabled = rows[1].mean_ns;
    let sampled_out = rows[2].mean_ns;
    assert!(
        disabled * 5.0 <= record + 200.0,
        "disabled span path ({disabled:.0}ns) is not ~free vs record ({record:.0}ns)"
    );
    assert!(
        sampled_out <= record * 2.0 + 200.0,
        "sampled-out span path ({sampled_out:.0}ns) costs more than recording ({record:.0}ns)"
    );
}
