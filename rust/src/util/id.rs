//! Monotonic ID generation for jobs, pods, and RPC requests.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide monotonic counter, namespaced by a prefix at format time.
#[derive(Debug)]
pub struct IdGen {
    next: AtomicU64,
}

impl IdGen {
    pub const fn new() -> Self {
        IdGen { next: AtomicU64::new(1) }
    }

    pub fn next(&self) -> u64 {
        self.next.fetch_add(1, Ordering::Relaxed)
    }
}

impl Default for IdGen {
    fn default() -> Self {
        Self::new()
    }
}

/// A Torque job id, formatted `<seq>.<server>` as PBS does (e.g. `42.torque-head`).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId {
    pub seq: u64,
    pub server: String,
}

impl JobId {
    pub fn new(seq: u64, server: impl Into<String>) -> Self {
        JobId { seq, server: server.into() }
    }

    /// Parse `42.torque-head` (as printed by qsub/qstat).
    pub fn parse(s: &str) -> Option<JobId> {
        let (seq, server) = s.split_once('.')?;
        Some(JobId { seq: seq.parse().ok()?, server: server.to_string() })
    }
}

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.{}", self.seq, self.server)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idgen_monotonic() {
        let g = IdGen::new();
        let a = g.next();
        let b = g.next();
        assert!(b > a);
    }

    #[test]
    fn jobid_roundtrip() {
        let id = JobId::new(42, "torque-head");
        assert_eq!(id.to_string(), "42.torque-head");
        assert_eq!(JobId::parse("42.torque-head"), Some(id));
        assert_eq!(JobId::parse("garbage"), None);
        assert_eq!(JobId::parse("x.head"), None);
    }
}
