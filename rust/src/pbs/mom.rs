//! pbs_mom: the per-node execution daemon.
//!
//! The server dispatches a launch to the *first* node of a job's placement
//! (Torque runs the batch script on the head chunk; other chunks only
//! reserve resources). The mom interprets the script body through the
//! shell substrate, enforces walltime with a timer, writes the `-o`/`-e`
//! output files into the shared FS, and reports completion.

use crate::cluster::{Metrics, NodeSpec, SharedFs};
use crate::rt::{self, Shutdown, Timers};
use crate::singularity::{CancelToken, Runtime};
use std::collections::HashMap;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which WLM this execution daemon serves (controls the job environment:
/// `PBS_*` for pbs_mom, `SLURM_*` for slurmd). The daemon logic is
/// otherwise identical, so the Slurm baseline reuses this type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WlmFlavor {
    #[default]
    Pbs,
    Slurm,
}

/// Server → mom launch order.
#[derive(Debug, Clone)]
pub struct LaunchSpec {
    pub job_seq: u64,
    pub job_name: String,
    pub body: Vec<String>,
    pub env: Vec<(String, String)>,
    pub stdout_path: Option<String>,
    pub stderr_path: Option<String>,
    /// Nominal walltime; the mom scales it by `time_scale` for enforcement.
    pub walltime: Duration,
    pub seed: u64,
}

/// Mom → server completion report.
#[derive(Debug, Clone)]
pub struct JobDone {
    pub job_seq: u64,
    pub node: String,
    pub exit_code: i32,
    pub cancelled: bool,
    pub walltime_exceeded: bool,
    pub wall: Duration,
}

struct Running {
    cancel: CancelToken,
}

/// One node daemon. Cheap to clone (shared state).
#[derive(Clone)]
pub struct Mom {
    pub spec: NodeSpec,
    fs: SharedFs,
    runtime: Runtime,
    timers: Timers,
    time_scale: f64,
    done_tx: Sender<JobDone>,
    running: Arc<Mutex<HashMap<u64, Running>>>,
    metrics: Metrics,
    shutdown: Shutdown,
    flavor: WlmFlavor,
}

impl Mom {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        spec: NodeSpec,
        fs: SharedFs,
        runtime: Runtime,
        timers: Timers,
        time_scale: f64,
        done_tx: Sender<JobDone>,
        metrics: Metrics,
        shutdown: Shutdown,
    ) -> Mom {
        Mom {
            spec,
            fs,
            runtime,
            timers,
            time_scale,
            done_tx,
            running: Arc::new(Mutex::new(HashMap::new())),
            metrics,
            shutdown,
            flavor: WlmFlavor::Pbs,
        }
    }

    /// Switch the job-environment flavor (slurmd reuses this daemon).
    pub fn with_flavor(mut self, flavor: WlmFlavor) -> Mom {
        self.flavor = flavor;
        self
    }

    pub fn node_name(&self) -> &str {
        &self.spec.name
    }

    /// Number of jobs currently executing on this node.
    pub fn active_jobs(&self) -> usize {
        self.running.lock().unwrap().len()
    }

    /// Start executing a job (returns immediately).
    pub fn launch(&self, spec: LaunchSpec) {
        let cancel = CancelToken::new();
        let walltime_hit = Arc::new(std::sync::atomic::AtomicBool::new(false));
        self.running.lock().unwrap().insert(spec.job_seq, Running { cancel: cancel.clone() });
        // Walltime enforcement: scaled to testbed time.
        let scaled = Duration::from_secs_f64(
            (spec.walltime.as_secs_f64() * self.time_scale).max(0.0),
        );
        let timer_cancel = cancel.clone();
        let timer_hit = walltime_hit.clone();
        let timer_id = self.timers.after(scaled, move || {
            timer_hit.store(true, std::sync::atomic::Ordering::SeqCst);
            timer_cancel.trigger();
        });

        let mom = self.clone();
        rt::spawn_named(&format!("mom-{}-job{}", self.spec.name, spec.job_seq), move || {
            let t0 = Instant::now();
            let mut ctx = crate::singularity::shell::ShellCtx::new(
                mom.fs.clone(),
                mom.runtime.clone(),
                cancel.clone(),
            );
            ctx.time_scale = mom.time_scale;
            ctx.seed = spec.seed;
            match mom.flavor {
                WlmFlavor::Pbs => {
                    ctx.env.insert("PBS_JOBID".into(), spec.job_seq.to_string());
                    ctx.env.insert("PBS_JOBNAME".into(), spec.job_name.clone());
                    ctx.env.insert("PBS_NODENAME".into(), mom.spec.name.clone());
                }
                WlmFlavor::Slurm => {
                    ctx.env.insert("SLURM_JOB_ID".into(), spec.job_seq.to_string());
                    ctx.env.insert("SLURM_JOB_NAME".into(), spec.job_name.clone());
                    ctx.env.insert("SLURMD_NODENAME".into(), mom.spec.name.clone());
                }
            }
            for (k, v) in &spec.env {
                ctx.env.insert(k.clone(), v.clone());
            }
            let exit_code = ctx.run_script(&spec.body);
            let wall = t0.elapsed();
            // Stage output files like pbs_mom's epilogue.
            let stdout_path = spec
                .stdout_path
                .clone()
                .unwrap_or_else(|| format!("$HOME/{}.o{}", spec.job_name, spec.job_seq));
            let stderr_path = spec
                .stderr_path
                .clone()
                .unwrap_or_else(|| format!("$HOME/{}.e{}", spec.job_name, spec.job_seq));
            let _ = mom.fs.write(&stdout_path, ctx.stdout.as_bytes());
            let _ = mom.fs.write(&stderr_path, ctx.stderr.as_bytes());
            mom.timers.cancel(timer_id);
            let hit = walltime_hit.load(std::sync::atomic::Ordering::SeqCst);
            let cancelled = cancel.is_triggered();
            mom.running.lock().unwrap().remove(&spec.job_seq);
            mom.metrics.inc("mom.jobs_completed");
            if hit {
                mom.metrics.inc("mom.walltime_kills");
            }
            if mom.shutdown.is_triggered() {
                return; // server tearing down: do not report
            }
            let _ = mom.done_tx.send(JobDone {
                job_seq: spec.job_seq,
                node: mom.spec.name.clone(),
                exit_code,
                cancelled,
                walltime_exceeded: hit,
                wall,
            });
        });
    }

    /// Kill a job (qdel). No-op if not running here.
    pub fn cancel(&self, job_seq: u64) {
        if let Some(r) = self.running.lock().unwrap().get(&job_seq) {
            r.cancel.trigger();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeRole, Resources};
    use crate::singularity::{ImageRegistry, RuntimeKind};
    use std::sync::mpsc::channel;

    fn mom_with(time_scale: f64) -> (Mom, std::sync::mpsc::Receiver<JobDone>, Shutdown) {
        let sd = Shutdown::new();
        let (timers, _h) = Timers::start(sd.clone());
        let (tx, rx) = channel();
        let fs = SharedFs::new();
        let runtime = Runtime::new(
            RuntimeKind::Singularity,
            ImageRegistry::with_defaults(),
            Metrics::new(),
        );
        let spec = NodeSpec::new("cn01", NodeRole::TorqueCompute, Resources::cores(8, 32 << 30));
        let mom =
            Mom::new(spec, fs, runtime, timers, time_scale, tx, Metrics::new(), sd.clone());
        (mom, rx, sd)
    }

    fn spec(seq: u64, body: &[&str], wall_s: u64) -> LaunchSpec {
        LaunchSpec {
            job_seq: seq,
            job_name: "t".into(),
            body: body.iter().map(|s| s.to_string()).collect(),
            env: Vec::new(),
            stdout_path: None,
            stderr_path: None,
            walltime: Duration::from_secs(wall_s),
            seed: 0,
        }
    }

    #[test]
    fn runs_script_and_reports() {
        let (mom, rx, sd) = mom_with(1.0);
        mom.launch(spec(1, &["echo hello"], 60));
        let done = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(done.exit_code, 0);
        assert!(!done.cancelled);
        assert_eq!(done.job_seq, 1);
        assert_eq!(done.node, "cn01");
        sd.trigger();
    }

    #[test]
    fn writes_default_output_files() {
        let (mom, rx, sd) = mom_with(1.0);
        mom.launch(spec(7, &["echo to stdout", "frobnicate"], 60));
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(mom.fs.read_string("$HOME/t.o7").unwrap(), "to stdout\n");
        assert!(mom.fs.read_string("$HOME/t.e7").unwrap().contains("command not found"));
        sd.trigger();
    }

    #[test]
    fn pbs_environment_exposed() {
        let (mom, rx, sd) = mom_with(1.0);
        mom.launch(spec(3, &["echo job=$PBS_JOBID name=$PBS_JOBNAME node=$PBS_NODENAME"], 60));
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(mom.fs.read_string("$HOME/t.o3").unwrap(), "job=3 name=t node=cn01\n");
        sd.trigger();
    }

    #[test]
    fn walltime_kill() {
        // time_scale=0.01: a 5s walltime becomes 50ms; the job sleeps "10s"
        // (scaled 100ms) and must be killed at the walltime.
        let (mom, rx, sd) = mom_with(0.01);
        mom.launch(spec(9, &["sleep 10", "echo survived"], 5));
        let done = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(done.walltime_exceeded, "{done:?}");
        assert!(done.cancelled);
        assert_eq!(done.exit_code, 137);
        let out = mom.fs.read_string("$HOME/t.o9").unwrap();
        assert!(!out.contains("survived"));
        sd.trigger();
    }

    #[test]
    fn explicit_cancel() {
        let (mom, rx, sd) = mom_with(1.0);
        mom.launch(spec(4, &["sleep 30"], 600));
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(mom.active_jobs(), 1);
        mom.cancel(4);
        let done = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(done.cancelled);
        assert!(!done.walltime_exceeded);
        assert_eq!(mom.active_jobs(), 0);
        sd.trigger();
    }

    #[test]
    fn custom_output_paths() {
        let (mom, rx, sd) = mom_with(1.0);
        let mut s = spec(5, &["echo custom"], 60);
        s.stdout_path = Some("$HOME/low.out".into());
        s.stderr_path = Some("$HOME/low.err".into());
        mom.launch(s);
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(mom.fs.read_string("$HOME/low.out").unwrap(), "custom\n");
        assert_eq!(mom.fs.read_string("$HOME/low.err").unwrap(), "");
        sd.trigger();
    }
}
