//! API server: the front door of the Kubernetes cluster.
//!
//! In-process callers (scheduler, kubelets, controllers, operators) use the
//! [`ApiServer`] handle directly; remote callers (the `hpcorc kubectl` CLI)
//! reach the same surface through a red-box RPC service (`kube.Api/*`),
//! mirroring how the paper's login node hosts both the k8s master and the
//! Unix-socket bridge.

use super::api::KubeObject;
use super::store::{Store, WatchEvent};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::redbox::{RedboxClient, Service};
use crate::util::{Error, Result};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// The API server handle (cheap clone; shares the store).
#[derive(Clone)]
pub struct ApiServer {
    store: Store,
    metrics: Metrics,
}

impl ApiServer {
    pub fn new(metrics: Metrics) -> ApiServer {
        ApiServer { store: Store::new(), metrics }
    }

    pub fn now_s(&self) -> f64 {
        self.store.now_s()
    }

    pub fn create(&self, obj: KubeObject) -> Result<KubeObject> {
        self.metrics.inc("kube.api.create");
        self.store.create(obj)
    }

    pub fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.metrics.inc("kube.api.get");
        self.store.get(kind, name)
    }

    /// Full update (spec + status) with optimistic concurrency.
    pub fn update(&self, obj: KubeObject) -> Result<KubeObject> {
        self.metrics.inc("kube.api.update");
        self.store.update(obj)
    }

    /// Status-subresource style update with retry-on-conflict: fetches the
    /// latest object and applies `f` until it commits (bounded attempts).
    pub fn update_status(
        &self,
        kind: &str,
        name: &str,
        f: impl Fn(&mut KubeObject),
    ) -> Result<KubeObject> {
        for _ in 0..16 {
            let mut obj = self.store.get(kind, name)?;
            f(&mut obj);
            match self.store.update(obj) {
                Ok(o) => {
                    self.metrics.inc("kube.api.update_status");
                    return Ok(o);
                }
                Err(e) if e.is_conflict() => continue,
                Err(e) => return Err(e),
            }
        }
        Err(Error::conflict(kind, name))
    }

    pub fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.metrics.inc("kube.api.delete");
        // Cascade: delete objects owned by this one first.
        let owned: Vec<KubeObject> = self
            .store
            .list_all()
            .into_iter()
            .filter(|o| {
                o.meta.owner.as_ref().map(|(k, n)| k == kind && n == name).unwrap_or(false)
            })
            .collect();
        for o in owned {
            let _ = self.delete(&o.kind, &o.meta.name);
        }
        self.store.delete(kind, name)
    }

    pub fn list(&self, kind: &str, selector: &[(String, String)]) -> Vec<KubeObject> {
        self.metrics.inc("kube.api.list");
        self.store.list(kind, selector)
    }

    pub fn current_version(&self) -> u64 {
        self.store.current_version()
    }

    pub fn watch(&self, kind: Option<&str>, from_version: u64) -> Receiver<WatchEvent> {
        self.metrics.inc("kube.api.watch");
        self.store.watch(kind, from_version)
    }

    /// `kubectl apply`: create, or update (spec-merge) when it exists.
    pub fn apply(&self, obj: KubeObject) -> Result<KubeObject> {
        match self.store.get(&obj.kind, &obj.meta.name) {
            Ok(existing) => {
                let mut merged = existing.clone();
                merged.spec = obj.spec;
                merged.meta.labels = obj.meta.labels;
                merged.meta.annotations = obj.meta.annotations;
                self.store.update(merged)
            }
            Err(e) if e.is_not_found() => self.store.create(obj),
            Err(e) => Err(e),
        }
    }

    /// Expose this API over a red-box service registry name `kube.Api`.
    pub fn rpc_service(&self) -> Arc<dyn Service> {
        Arc::new(ApiService { api: self.clone() })
    }
}

struct ApiService {
    api: ApiServer,
}

impl Service for ApiService {
    fn call(&self, method: &str, body: &Value) -> Result<Value> {
        match method {
            "Create" => Ok(self.api.create(KubeObject::decode(body)?)?.encode()),
            "Apply" => Ok(self.api.apply(KubeObject::decode(body)?)?.encode()),
            "Get" => {
                let o = self.api.get(body.req_str("kind")?, body.req_str("name")?)?;
                Ok(o.encode())
            }
            "Delete" => {
                let o = self.api.delete(body.req_str("kind")?, body.req_str("name")?)?;
                Ok(o.encode())
            }
            "List" => {
                let kind = body.req_str("kind")?;
                let items = self.api.list(kind, &[]);
                Ok(Value::map()
                    .with("serverSeconds", self.api.now_s())
                    .with("items", Value::Seq(items.iter().map(|o| o.encode()).collect())))
            }
            other => Err(Error::rpc(format!("kube.Api has no method `{other}`"))),
        }
    }
}

/// Client-side mirror of the RPC surface (used by the CLI).
pub struct RemoteApi {
    client: RedboxClient,
}

impl RemoteApi {
    pub fn new(client: RedboxClient) -> RemoteApi {
        RemoteApi { client }
    }

    pub fn apply(&self, obj: &KubeObject) -> Result<KubeObject> {
        KubeObject::decode(&self.client.call("kube.Api/Apply", obj.encode())?)
    }

    pub fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        KubeObject::decode(
            &self
                .client
                .call("kube.Api/Get", Value::map().with("kind", kind).with("name", name))?,
        )
    }

    pub fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        KubeObject::decode(
            &self
                .client
                .call("kube.Api/Delete", Value::map().with("kind", kind).with("name", name))?,
        )
    }

    /// Returns (server time, items) — server time drives AGE columns.
    pub fn list(&self, kind: &str) -> Result<(f64, Vec<KubeObject>)> {
        let v = self.client.call("kube.Api/List", Value::map().with("kind", kind))?;
        let now = v.get("serverSeconds").and_then(Value::as_f64).unwrap_or(0.0);
        let items = v
            .get("items")
            .and_then(Value::as_seq)
            .map(|s| s.iter().map(KubeObject::decode).collect::<Result<Vec<_>>>())
            .transpose()?
            .unwrap_or_default();
        Ok((now, items))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Value;
    use crate::kube::api::{KIND_DEPLOYMENT, KIND_POD};
    use crate::redbox::RedboxServer;
    use crate::rt::Shutdown;

    fn api() -> ApiServer {
        ApiServer::new(Metrics::new())
    }

    fn pod(name: &str) -> KubeObject {
        KubeObject::new(KIND_POD, name, Value::map().with("v", 1i64))
    }

    #[test]
    fn update_status_retries_conflicts() {
        let a = api();
        a.create(pod("p")).unwrap();
        // Interleave an update between get and commit by doing it inside f
        // on the first call only.
        let api2 = a.clone();
        let first = std::sync::atomic::AtomicBool::new(true);
        let out = a
            .update_status(KIND_POD, "p", |o| {
                if first.swap(false, std::sync::atomic::Ordering::SeqCst) {
                    // racey writer bumps the version under us
                    api2.update_status(KIND_POD, "p", |o2| {
                        o2.status.insert("other", "x");
                    })
                    .unwrap();
                }
                o.status.insert("phase", "Running");
            })
            .unwrap();
        assert_eq!(out.status.opt_str("phase"), Some("Running"));
        assert_eq!(out.status.opt_str("other"), Some("x"), "racey write preserved");
    }

    #[test]
    fn cascade_delete_by_owner() {
        let a = api();
        a.create(KubeObject::new(KIND_DEPLOYMENT, "web", Value::map())).unwrap();
        let mut p = pod("web-1");
        p.meta.owner = Some((KIND_DEPLOYMENT.into(), "web".into()));
        a.create(p).unwrap();
        a.create(pod("standalone")).unwrap();
        a.delete(KIND_DEPLOYMENT, "web").unwrap();
        assert!(a.get(KIND_POD, "web-1").unwrap_err().is_not_found());
        assert!(a.get(KIND_POD, "standalone").is_ok());
    }

    #[test]
    fn apply_create_then_merge() {
        let a = api();
        let o1 = a.apply(pod("p")).unwrap();
        a.update_status(KIND_POD, "p", |o| o.status.insert("phase", "Running")).unwrap();
        // Re-apply with changed spec: spec replaced, status preserved.
        let mut newer = pod("p");
        newer.spec.insert("v", 2i64);
        let o2 = a.apply(newer).unwrap();
        assert!(o2.meta.resource_version > o1.meta.resource_version);
        assert_eq!(o2.spec.opt_int("v"), Some(2));
        assert_eq!(o2.status.opt_str("phase"), Some("Running"));
    }

    #[test]
    fn rpc_surface_end_to_end() {
        let sd = Shutdown::new();
        let path = std::env::temp_dir()
            .join(format!("hpcorc-kubeapi-{}.sock", std::process::id()));
        let mut srv = RedboxServer::start(&path, sd.clone(), Metrics::new()).unwrap();
        let a = api();
        srv.register("kube.Api", a.rpc_service());
        let remote = RemoteApi::new(RedboxClient::connect(&path).unwrap());

        let created = remote.apply(&pod("rp")).unwrap();
        assert!(created.meta.uid > 0);
        let got = remote.get(KIND_POD, "rp").unwrap();
        assert_eq!(got.meta.uid, created.meta.uid);
        let (now, items) = remote.list(KIND_POD).unwrap();
        assert!(now >= 0.0);
        assert_eq!(items.len(), 1);
        remote.delete(KIND_POD, "rp").unwrap();
        assert!(remote.get(KIND_POD, "rp").is_err());
        srv.stop();
    }
}
