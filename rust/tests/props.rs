//! Randomized property tests (proptest substitute — the offline registry
//! has no proptest, so properties are swept with the crate's own seeded
//! RNG across many cases; failures print the seed for reproduction).

use hpcorc::encoding::{json, yaml, Value};
use hpcorc::sched::{EasyBackfill, FifoPolicy, KubeGreedyPolicy, NodeState, PendingJob, SchedPolicy};
use hpcorc::sim::{simulate, SimParams};
use hpcorc::util::Rng;
use hpcorc::workload::TraceGen;

/// Random Value trees for codec roundtrips.
fn arb_value(rng: &mut Rng, depth: u32) -> Value {
    match if depth == 0 { rng.below(5) } else { rng.below(7) } {
        0 => Value::Null,
        1 => Value::Bool(rng.chance(0.5)),
        2 => Value::Int(rng.next_u64() as i64 >> rng.below(40)),
        3 => Value::Float((rng.f64() - 0.5) * 1e6),
        4 => {
            let n = rng.below(12) as usize;
            Value::Str((0..n).map(|_| random_char(rng)).collect())
        }
        5 => {
            let n = rng.below(4) as usize;
            Value::Seq((0..n).map(|_| arb_value(rng, depth - 1)).collect())
        }
        _ => {
            let n = rng.below(4) as usize;
            Value::Map(
                (0..n)
                    .map(|i| (format!("k{}{}", i, rng.suffix(3)), arb_value(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

fn random_char(rng: &mut Rng) -> char {
    match rng.below(10) {
        0 => '\n',
        1 => '"',
        2 => '\\',
        3 => 'ü',
        4 => '🐍',
        5 => '#',
        6 => ':',
        _ => (b'a' + rng.below(26) as u8) as char,
    }
}

#[test]
fn prop_json_roundtrip() {
    for seed in 0..300 {
        let mut rng = Rng::new(seed);
        let v = arb_value(&mut rng, 3);
        let s = json::to_string(&v);
        let back = json::parse(&s).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{s}"));
        assert_eq!(back, v, "seed {seed}: {s}");
    }
}

#[test]
fn prop_yaml_emit_parse_roundtrip() {
    for seed in 0..300 {
        let mut rng = Rng::new(1000 + seed);
        // YAML emitter targets maps at the top level (manifests).
        let v = Value::Map(
            (0..1 + rng.below(3) as usize)
                .map(|i| (format!("key{i}"), arb_value(&mut rng, 2)))
                .collect(),
        );
        let y = yaml::to_string(&v);
        let back = yaml::parse(&y).unwrap_or_else(|e| panic!("seed {seed}: {e}\n---\n{y}"));
        assert_eq!(back, v, "seed {seed}:\n{y}");
    }
}

#[test]
fn prop_schedulers_never_overcommit_and_respect_feasibility() {
    for seed in 0..200 {
        let mut rng = Rng::new(2000 + seed);
        let n_nodes = 1 + rng.below(8) as usize;
        let cores = 1 + rng.below(16) as u32;
        let nodes: Vec<NodeState> = (0..n_nodes)
            .map(|i| {
                let mut n = NodeState::whole(i, cores, 1 << 30);
                n.free_cores = rng.below(cores as u64 + 1) as u32;
                n
            })
            .collect();
        let pending: Vec<PendingJob> = (0..rng.below(20))
            .map(|id| {
                let mut j = PendingJob::simple(
                    id,
                    1 + rng.below(4) as u32,
                    1 + rng.below(8) as u32,
                    1 + rng.below(1000),
                );
                j.priority = rng.below(5) as i64;
                j.submit_s = rng.f64() * 100.0;
                j
            })
            .collect();
        for policy in [&FifoPolicy as &dyn SchedPolicy, &EasyBackfill, &KubeGreedyPolicy] {
            let out = policy.schedule(100.0, &pending, &nodes, &[]);
            // Each assignment fits within the node's free capacity, summed.
            let mut used = vec![0u32; n_nodes];
            for a in &out {
                let job = pending.iter().find(|j| j.id == a.job).unwrap();
                assert_eq!(a.placement.len(), job.nodes as usize, "seed {seed}");
                let mut nodes_seen = std::collections::HashSet::new();
                for p in &a.placement {
                    assert!(nodes_seen.insert(p.node), "seed {seed}: duplicate node in one job");
                    used[p.node] += p.cores;
                }
            }
            for (i, u) in used.iter().enumerate() {
                assert!(
                    *u <= nodes[i].free_cores,
                    "seed {seed} policy {}: node {i} overcommitted {u}>{}",
                    policy.name(),
                    nodes[i].free_cores
                );
            }
            // No job assigned twice.
            let mut ids: Vec<u64> = out.iter().map(|a| a.job).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), out.len(), "seed {seed}");
        }
    }
}

#[test]
fn prop_sim_invariants_across_policies_and_traces() {
    for seed in 0..20 {
        let trace = TraceGen::new(3000 + seed).poisson_batch(
            100 + (seed as usize * 13) % 150,
            64,
            0.5 + (seed as f64 % 5.0) / 10.0,
            60.0,
        );
        let params = SimParams { nodes: 8, cores_per_node: 8, ..SimParams::default() };
        for policy in [&FifoPolicy as &dyn SchedPolicy, &EasyBackfill, &KubeGreedyPolicy] {
            let r = simulate(&trace, &params, policy);
            assert!(r.utilization <= 1.0 + 1e-9, "seed {seed} {}", r.policy);
            assert!(r.completed <= trace.len());
            assert!(r.mean_wait_s <= r.max_wait_s + 1e-9);
            assert!(r.p95_wait_s <= r.max_wait_s + 1e-9);
            assert!(
                r.makespan_s + 1e-6
                    >= trace.jobs.iter().map(|j| j.runtime_s).fold(0.0, f64::max),
                "seed {seed}: makespan shorter than longest job"
            );
            // EASY never loses to FIFO by more than noise on makespan
            // (EASY only *adds* backfill starts).
        }
        let fifo = simulate(&trace, &params, &FifoPolicy);
        let easy = simulate(&trace, &params, &EasyBackfill);
        assert!(
            easy.makespan_s <= fifo.makespan_s * 1.05 + 1.0,
            "seed {seed}: EASY much worse than FIFO ({} vs {})",
            easy.makespan_s,
            fifo.makespan_s
        );
    }
}

#[test]
fn prop_pbs_script_parse_render_fixpoint() {
    for seed in 0..100 {
        let mut rng = Rng::new(4000 + seed);
        let mut script = hpcorc::pbs::PbsScript::default();
        if rng.chance(0.7) {
            script.name = Some(format!("job{}", rng.suffix(4)));
        }
        script.nodes = 1 + rng.below(8) as u32;
        script.ppn = 1 + rng.below(8) as u32;
        script.priority = rng.below(20) as i64 - 10;
        script.walltime = std::time::Duration::from_secs(60 + rng.below(100_000));
        if rng.chance(0.5) {
            script.mem = (1 + rng.below(64)) << 20;
        }
        if rng.chance(0.5) {
            script.stdout_path = Some(format!("$HOME/{}.out", rng.suffix(3)));
        }
        script.body = vec!["echo body".to_string()];
        let rendered = script.render();
        let parsed = hpcorc::pbs::PbsScript::parse(&rendered)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{rendered}"));
        assert_eq!(parsed, script, "seed {seed}:\n{rendered}");
    }
}
