//! YAML-subset parser and emitter over [`Value`].
//!
//! The offline registry has no serde_yaml, so we implement the subset of
//! YAML that Kubernetes manifests actually use (and that the paper's Fig. 3
//! `cow_job.yaml` exercises):
//!
//! - block mappings and sequences nested by indentation
//! - `- ` sequence items, including compact `- key: value` map starts
//! - plain / single-quoted / double-quoted scalars (JSON escapes in double)
//! - block literal scalars `|`, `|-`, `|+` (the PBS script in `spec.batch`)
//!   and folded `>`, `>-`
//! - flow collections `[a, b]` and `{k: v}` one level deep or nested
//! - `#` comments, blank lines, `---` document separators
//! - scalar typing: null/~, booleans, ints, floats, everything else string
//!
//! Not supported (rejected with a parse error where detectable): anchors &
//! aliases, tags, complex keys, tab indentation.

use super::value::Value;
use crate::util::{Error, Result};

// ----------------------------------------------------------------- parsing

/// Parse a single-document YAML string.
pub fn parse(src: &str) -> Result<Value> {
    let docs = parse_all(src)?;
    match docs.len() {
        0 => Ok(Value::Null),
        1 => Ok(docs.into_iter().next().unwrap()),
        n => Err(Error::parse(format!("expected 1 document, found {n}"))),
    }
}

/// Parse a multi-document YAML stream separated by `---`.
pub fn parse_all(src: &str) -> Result<Vec<Value>> {
    let mut docs = Vec::new();
    let mut current: Vec<&str> = Vec::new();
    for line in src.lines() {
        if line.trim_end() == "---" {
            if !current.is_empty() {
                docs.push(parse_doc(&current)?);
                current.clear();
            }
        } else if line.trim_end() == "..." {
            // explicit end-of-document
            if !current.is_empty() {
                docs.push(parse_doc(&current)?);
                current.clear();
            }
        } else {
            current.push(line);
        }
    }
    if current.iter().any(|l| !is_blank_or_comment(l)) {
        docs.push(parse_doc(&current)?);
    }
    Ok(docs)
}

fn is_blank_or_comment(line: &str) -> bool {
    let t = line.trim_start();
    t.is_empty() || t.starts_with('#')
}

struct Line<'a> {
    indent: usize,
    /// content after indentation (non-empty, not a pure comment)
    text: &'a str,
    /// 1-based source line number for errors
    no: usize,
}

fn parse_doc(lines: &[&str]) -> Result<Value> {
    let mut parsed = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        if raw.contains('\t') && raw.trim_start_matches(' ').starts_with('\t') {
            return Err(Error::parse(format!("line {}: tab indentation", i + 1)));
        }
        // Keep blank/comment lines out, but note: block-literal bodies are
        // re-read from `lines` directly via their line numbers, so nothing
        // inside a literal is lost.
        if is_blank_or_comment(raw) {
            continue;
        }
        let indent = raw.len() - raw.trim_start_matches(' ').len();
        parsed.push(Line { indent, text: raw[indent..].trim_end(), no: i + 1 });
    }
    if parsed.is_empty() {
        return Ok(Value::Null);
    }
    let mut cur = Cursor { lines: &parsed, raw: lines, pos: 0 };
    let v = cur.block(parsed[0].indent)?;
    if cur.pos != parsed.len() {
        let l = &parsed[cur.pos];
        return Err(Error::parse(format!("line {}: unexpected content `{}`", l.no, l.text)));
    }
    Ok(v)
}

struct Cursor<'a, 'b> {
    lines: &'b [Line<'a>],
    /// original raw lines (for block literals)
    raw: &'b [&'a str],
    pos: usize,
}

impl<'a, 'b> Cursor<'a, 'b> {
    fn peek(&self) -> Option<&Line<'a>> {
        self.lines.get(self.pos)
    }

    /// Parse a block (mapping or sequence) whose items sit at `indent`.
    fn block(&mut self, indent: usize) -> Result<Value> {
        let first = self.peek().ok_or_else(|| Error::parse("empty block"))?;
        if first.text == "-" || first.text.starts_with("- ") {
            self.sequence(indent)
        } else {
            self.mapping(indent)
        }
    }

    fn sequence(&mut self, indent: usize) -> Result<Value> {
        let mut items = Vec::new();
        while let Some(line) = self.peek() {
            if line.indent != indent || !(line.text == "-" || line.text.starts_with("- ")) {
                break;
            }
            let no = line.no;
            let rest = line.text[1..].trim_start().to_string();
            self.pos += 1;
            if rest.is_empty() {
                // nested block on following deeper lines
                match self.peek() {
                    Some(next) if next.indent > indent => {
                        let v = self.block(next.indent)?;
                        items.push(v);
                    }
                    _ => items.push(Value::Null),
                }
            } else if let Some((key, val_text)) = split_map_key(&rest) {
                // compact mapping start: `- name: data`
                // Continuation keys are indented past the dash.
                let item_indent = indent + 2;
                let mut map = Vec::new();
                let v = self.map_value(&val_text, item_indent, no)?;
                map.push((key, v));
                while let Some(next) = self.peek() {
                    if next.indent != item_indent
                        || next.text.starts_with("- ")
                        || next.text == "-"
                    {
                        break;
                    }
                    let (k, vt) = split_map_key(next.text).ok_or_else(|| {
                        Error::parse(format!("line {}: expected `key:`", next.no))
                    })?;
                    let nno = next.no;
                    self.pos += 1;
                    let v = self.map_value(&vt, item_indent, nno)?;
                    map.push((k, v));
                }
                items.push(Value::Map(map));
            } else {
                items.push(parse_scalar(&rest)?);
            }
        }
        Ok(Value::Seq(items))
    }

    fn mapping(&mut self, indent: usize) -> Result<Value> {
        let mut map: Vec<(String, Value)> = Vec::new();
        while let Some(line) = self.peek() {
            if line.indent != indent {
                break;
            }
            if line.text == "-" || line.text.starts_with("- ") {
                break;
            }
            let (key, val_text) = split_map_key(line.text).ok_or_else(|| {
                Error::parse(format!("line {}: expected `key: value`", line.no))
            })?;
            if map.iter().any(|(k, _)| *k == key) {
                return Err(Error::parse(format!("line {}: duplicate key `{key}`", line.no)));
            }
            let no = line.no;
            self.pos += 1;
            let v = self.map_value(&val_text, indent, no)?;
            map.push((key, v));
        }
        Ok(Value::Map(map))
    }

    /// Parse the value position of a mapping entry. `val_text` is what
    /// followed `key:` on the same line (may be empty), `indent` the key's
    /// indentation, `no` its line number.
    fn map_value(&mut self, val_text: &str, indent: usize, no: usize) -> Result<Value> {
        let vt = val_text.trim();
        if vt.is_empty() {
            // Nested block, or null if nothing deeper follows. A sequence
            // under a key may sit at the SAME indent as the key (k8s style).
            match self.peek() {
                Some(next)
                    if next.indent > indent
                        || (next.indent == indent
                            && (next.text == "-" || next.text.starts_with("- "))) =>
                {
                    let child_indent = next.indent;
                    self.block(child_indent)
                }
                _ => Ok(Value::Null),
            }
        } else if vt == "|" || vt == "|-" || vt == "|+" || vt == ">" || vt == ">-" {
            self.block_scalar(vt, indent, no)
        } else {
            parse_scalar(vt)
        }
    }

    /// Block literal/folded scalar. Reads from the RAW lines following line
    /// `no` (blank lines inside the block are significant).
    fn block_scalar(&mut self, marker: &str, key_indent: usize, no: usize) -> Result<Value> {
        // Collect raw lines after `no` that are blank or indented > key_indent.
        let mut body: Vec<&str> = Vec::new();
        let mut raw_idx = no; // `no` is 1-based; raw[no] is the next line
        while raw_idx < self.raw.len() {
            let l = self.raw[raw_idx];
            let trimmed = l.trim_end();
            if trimmed.is_empty() {
                body.push("");
                raw_idx += 1;
                continue;
            }
            let ind = l.len() - l.trim_start_matches(' ').len();
            if ind <= key_indent {
                break;
            }
            body.push(trimmed);
            raw_idx += 1;
        }
        // Trim trailing blank lines from the body (they belong to the doc).
        while body.last() == Some(&"") {
            body.pop();
        }
        // Advance the content cursor past every consumed content line.
        while let Some(line) = self.peek() {
            if line.no <= no || line.no > raw_idx {
                break;
            }
            self.pos += 1;
        }
        // Dedent by the first content line's indentation.
        let dedent = body
            .iter()
            .filter(|l| !l.is_empty())
            .map(|l| l.len() - l.trim_start_matches(' ').len())
            .next()
            .unwrap_or(0);
        let dedented: Vec<&str> =
            body.iter().map(|l| if l.len() >= dedent { &l[dedent..] } else { "" }).collect();
        let mut text = if marker.starts_with('>') {
            // folded: newlines become spaces (blank line => newline)
            let mut out = String::new();
            for (i, l) in dedented.iter().enumerate() {
                if l.is_empty() {
                    out.push('\n');
                } else {
                    if i > 0 && !out.ends_with('\n') && !out.is_empty() {
                        out.push(' ');
                    }
                    out.push_str(l);
                }
            }
            out
        } else {
            dedented.join("\n")
        };
        match marker {
            "|" | ">" => text.push('\n'),   // clip: single trailing newline
            "|-" | ">-" => {}               // strip
            "|+" => text.push('\n'),        // keep (equal to clip after our trim)
            _ => unreachable!(),
        }
        Ok(Value::Str(text))
    }
}

/// Split `key: value` — returns None if the line is not a mapping entry.
/// Handles quoted keys and `:` inside quotes.
fn split_map_key(text: &str) -> Option<(String, String)> {
    let bytes = text.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'\\' if in_double => i += 1,
            b':' if !in_single && !in_double => {
                // `:` must be followed by space/EOL to be a mapping separator
                if i + 1 == bytes.len() || bytes[i + 1] == b' ' {
                    let raw_key = text[..i].trim();
                    let key = unquote_key(raw_key)?;
                    let val = if i + 1 >= text.len() { "" } else { &text[i + 1..] };
                    return Some((key, val.trim().to_string()));
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

fn unquote_key(k: &str) -> Option<String> {
    if k.is_empty() {
        return None;
    }
    if (k.starts_with('"') && k.ends_with('"') && k.len() >= 2)
        || (k.starts_with('\'') && k.ends_with('\'') && k.len() >= 2)
    {
        Some(k[1..k.len() - 1].to_string())
    } else {
        Some(k.to_string())
    }
}

/// Parse a flow scalar / flow collection.
fn parse_scalar(s: &str) -> Result<Value> {
    let s = strip_inline_comment(s).trim();
    if s.is_empty() {
        return Ok(Value::Null);
    }
    if s.starts_with('[') || s.starts_with('{') {
        return parse_flow(s);
    }
    if s.starts_with('"') {
        // Reuse the JSON string parser for escapes.
        return super::json::parse(s);
    }
    if s.starts_with('\'') {
        if s.len() >= 2 && s.ends_with('\'') {
            return Ok(Value::Str(s[1..s.len() - 1].replace("''", "'")));
        }
        return Err(Error::parse(format!("unterminated single-quoted scalar `{s}`")));
    }
    if s.starts_with('&') || s.starts_with('*') {
        return Err(Error::parse(format!("anchors/aliases unsupported: `{s}`")));
    }
    Ok(plain_scalar(s))
}

/// Type a plain (unquoted) scalar per YAML core schema.
fn plain_scalar(s: &str) -> Value {
    match s {
        "null" | "Null" | "NULL" | "~" => return Value::Null,
        "true" | "True" | "TRUE" => return Value::Bool(true),
        "false" | "False" | "FALSE" => return Value::Bool(false),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Value::Int(i);
    }
    if looks_numeric(s) {
        if let Ok(f) = s.parse::<f64>() {
            return Value::Float(f);
        }
    }
    Value::Str(s.to_string())
}

/// Only treat as float what actually looks like a number (so `1.2.3`,
/// `e5`, version strings etc. stay strings).
fn looks_numeric(s: &str) -> bool {
    let t = s.strip_prefix(['-', '+']).unwrap_or(s);
    !t.is_empty()
        && t.chars().next().unwrap().is_ascii_digit()
        && t.chars().all(|c| c.is_ascii_digit() || c == '.' || c == 'e' || c == 'E' || c == '-' || c == '+')
        && t.matches('.').count() <= 1
}

fn strip_inline_comment(s: &str) -> &str {
    let bytes = s.as_bytes();
    let mut in_single = false;
    let mut in_double = false;
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\'' if !in_double => in_single = !in_single,
            b'"' if !in_single => in_double = !in_double,
            b'\\' if in_double => i += 1,
            b'#' if !in_single && !in_double && i > 0 && bytes[i - 1] == b' ' => {
                return &s[..i];
            }
            _ => {}
        }
        i += 1;
    }
    s
}

/// Minimal flow-collection parser: `[a, b, {k: v}]`, `{k: v, l: [1]}`.
fn parse_flow(s: &str) -> Result<Value> {
    let mut p = Flow { bytes: s.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::parse(format!("trailing flow content in `{s}`")));
    }
    Ok(v)
}

struct Flow<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Flow<'a> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t')) {
            self.pos += 1;
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.bytes.get(self.pos) {
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                loop {
                    self.skip_ws();
                    if self.bytes.get(self.pos) == Some(&b']') {
                        self.pos += 1;
                        return Ok(Value::Seq(items));
                    }
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.bytes.get(self.pos) {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {}
                        _ => return Err(Error::parse("expected `,` or `]` in flow seq")),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = Vec::new();
                loop {
                    self.skip_ws();
                    if self.bytes.get(self.pos) == Some(&b'}') {
                        self.pos += 1;
                        return Ok(Value::Map(map));
                    }
                    let key = self.token(&[b':'])?;
                    if self.bytes.get(self.pos) != Some(&b':') {
                        return Err(Error::parse("expected `:` in flow map"));
                    }
                    self.pos += 1;
                    let v = self.value()?;
                    map.push((key.trim().to_string(), v));
                    self.skip_ws();
                    match self.bytes.get(self.pos) {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {}
                        _ => return Err(Error::parse("expected `,` or `}` in flow map")),
                    }
                }
            }
            _ => {
                let tok = self.token(&[b',', b']', b'}'])?;
                parse_scalar(tok.trim())
            }
        }
    }

    /// Read a raw token until one of the terminator bytes (outside quotes).
    fn token(&mut self, terms: &[u8]) -> Result<&'a str> {
        let start = self.pos;
        let mut in_single = false;
        let mut in_double = false;
        while let Some(&b) = self.bytes.get(self.pos) {
            match b {
                b'\'' if !in_double => in_single = !in_single,
                b'"' if !in_single => in_double = !in_double,
                b'\\' if in_double => self.pos += 1,
                _ if !in_single && !in_double && terms.contains(&b) => break,
                _ => {}
            }
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::parse("invalid utf-8 in flow"))
    }
}

// ---------------------------------------------------------------- emitting

/// Emit a Value as block-style YAML (kubectl `-o yaml` look).
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    emit(v, 0, false, &mut out);
    if !out.ends_with('\n') {
        out.push('\n');
    }
    out
}

fn emit(v: &Value, indent: usize, inline: bool, out: &mut String) {
    match v {
        Value::Map(m) if m.is_empty() => out.push_str("{}"),
        Value::Seq(s) if s.is_empty() => out.push_str("[]"),
        Value::Map(m) => {
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 || !inline {
                    if i > 0 {
                        out.push('\n');
                    }
                    push_spaces(indent, out);
                }
                out.push_str(&emit_key(k));
                out.push(':');
                match val {
                    Value::Map(mm) if !mm.is_empty() => {
                        out.push('\n');
                        emit(val, indent + 2, false, out);
                    }
                    Value::Seq(ss) if !ss.is_empty() => {
                        out.push('\n');
                        emit(val, indent, false, out);
                    }
                    // Block literals: clip-style `|`/`|-` cannot represent
                    // multiple trailing newlines — quote those instead.
                    Value::Str(s) if s.contains('\n') && !s.ends_with("\n\n") => {
                        emit_block_literal(s, indent + 2, out);
                    }
                    _ => {
                        out.push(' ');
                        emit_scalar(val, out);
                    }
                }
            }
        }
        Value::Seq(s) => {
            for (i, item) in s.iter().enumerate() {
                if i > 0 {
                    out.push('\n');
                }
                push_spaces(indent, out);
                out.push_str("- ");
                match item {
                    Value::Map(m) if !m.is_empty() => emit(item, indent + 2, true, out),
                    Value::Seq(ss) if !ss.is_empty() => {
                        // nested sequence: put first item on next line
                        out.pop();
                        out.pop();
                        out.push_str("-\n");
                        emit(item, indent + 2, false, out);
                    }
                    Value::Str(st) if st.contains('\n') => {
                        // The parser does not accept `- |` block literals;
                        // emit multi-line sequence strings quoted instead.
                        out.push_str(&super::json::to_string(&Value::Str(st.clone())));
                    }
                    _ => emit_scalar(item, out),
                }
            }
        }
        scalar => emit_scalar(scalar, out),
    }
}

fn emit_block_literal(s: &str, indent: usize, out: &mut String) {
    if s.ends_with('\n') {
        out.push_str(" |");
    } else {
        out.push_str(" |-");
    }
    for line in s.trim_end_matches('\n').split('\n') {
        out.push('\n');
        if !line.is_empty() {
            push_spaces(indent, out);
            out.push_str(line);
        }
    }
}

fn push_spaces(n: usize, out: &mut String) {
    for _ in 0..n {
        out.push(' ');
    }
}

fn emit_key(k: &str) -> String {
    if k.is_empty() || k.contains(':') || k.contains('#') || k.starts_with(['-', ' ', '\'', '"']) {
        let mut s = String::new();
        super::json::to_string(&Value::str(k)).clone_into(&mut s);
        s
    } else {
        k.to_string()
    }
}

fn emit_scalar(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => {
            let s = format!("{f}");
            out.push_str(&s);
            if !s.contains('.') && !s.contains('e') {
                out.push_str(".0");
            }
        }
        Value::Str(s) => {
            if needs_quoting(s) {
                out.push_str(&super::json::to_string(&Value::Str(s.clone())));
            } else {
                out.push_str(s);
            }
        }
        // Empty containers render in flow style.
        Value::Map(m) if m.is_empty() => out.push_str("{}"),
        Value::Seq(s) if s.is_empty() => out.push_str("[]"),
        _ => unreachable!("emit_scalar on non-empty container"),
    }
}

fn needs_quoting(s: &str) -> bool {
    if s.is_empty() {
        return true;
    }
    // Would a plain re-parse change type or structure?
    !matches!(plain_scalar(s), Value::Str(_))
        || s.starts_with([' ', '-', '?', ':', '&', '*', '!', '|', '>', '%', '@', '`', '\'', '"', '[', ']', '{', '}', '#'])
        || s.ends_with(' ')
        || s.contains(": ")
        || s.ends_with(':')
        || s.contains(" #")
        || s.contains('\n')
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 3 manifest, verbatim structure.
    const COW_JOB: &str = r#"apiVersion: wlm.sylabs.io/v1alpha1
kind: TorqueJob
metadata:
  name: cow
spec:
  batch: |
    #!/bin/sh
    #PBS -l walltime=00:30:00
    #PBS -l nodes=1
    #PBS -e $HOME/low.err
    #PBS -o $HOME/low.out
    export PATH=$PATH:/usr/local/bin
    singularity run lolcow_latest.sif
  results:
    from: $HOME/low.out
  mount:
    name: data
    hostPath:
      path: $HOME/
      type: DirectoryOrCreate
"#;

    #[test]
    fn parses_paper_fig3_manifest() {
        let v = parse(COW_JOB).unwrap();
        assert_eq!(v.opt_str("kind"), Some("TorqueJob"));
        assert_eq!(v.path(&["metadata", "name"]).unwrap().as_str(), Some("cow"));
        let batch = v.path(&["spec", "batch"]).unwrap().as_str().unwrap();
        assert!(batch.starts_with("#!/bin/sh\n"));
        assert!(batch.contains("#PBS -l walltime=00:30:00"));
        assert!(batch.contains("singularity run lolcow_latest.sif"));
        assert!(batch.ends_with('\n'));
        assert_eq!(
            v.path(&["spec", "results", "from"]).unwrap().as_str(),
            Some("$HOME/low.out")
        );
        assert_eq!(
            v.path(&["spec", "mount", "hostPath", "type"]).unwrap().as_str(),
            Some("DirectoryOrCreate")
        );
    }

    #[test]
    fn roundtrip_fig3() {
        let v = parse(COW_JOB).unwrap();
        let emitted = to_string(&v);
        let back = parse(&emitted).unwrap();
        assert_eq!(back, v, "emitted:\n{emitted}");
    }

    #[test]
    fn sequences_of_maps() {
        let y = "containers:\n  - name: a\n    image: img:v1\n    args:\n      - run\n      - \"--fast\"\n  - name: b\n";
        let v = parse(y).unwrap();
        let cs = v.get("containers").unwrap().as_seq().unwrap();
        assert_eq!(cs.len(), 2);
        assert_eq!(cs[0].opt_str("image"), Some("img:v1"));
        assert_eq!(cs[0].get("args").unwrap().as_seq().unwrap()[1].as_str(), Some("--fast"));
        assert_eq!(cs[1].opt_str("name"), Some("b"));
    }

    #[test]
    fn sequence_at_key_indent() {
        // k8s style: list items at the same indent as the key
        let y = "spec:\n  tolerations:\n  - key: virtual-kubelet\n    value: torque\n";
        let v = parse(y).unwrap();
        let ts = v.path(&["spec", "tolerations"]).unwrap().as_seq().unwrap();
        assert_eq!(ts[0].opt_str("key"), Some("virtual-kubelet"));
    }

    #[test]
    fn scalar_typing() {
        let v = parse("a: 1\nb: 1.5\nc: true\nd: null\ne: ~\nf: hello\ng: \"2\"\nh: 1.2.3\n").unwrap();
        assert_eq!(v.get("a"), Some(&Value::Int(1)));
        assert_eq!(v.get("b"), Some(&Value::Float(1.5)));
        assert_eq!(v.get("c"), Some(&Value::Bool(true)));
        assert_eq!(v.get("d"), Some(&Value::Null));
        assert_eq!(v.get("e"), Some(&Value::Null));
        assert_eq!(v.get("f"), Some(&Value::str("hello")));
        assert_eq!(v.get("g"), Some(&Value::str("2")));
        assert_eq!(v.get("h"), Some(&Value::str("1.2.3")));
    }

    #[test]
    fn comments_and_blanks() {
        let y = "# header\na: 1 # trailing\n\n# mid\nb: 'x # not comment'\n";
        let v = parse(y).unwrap();
        assert_eq!(v.get("a"), Some(&Value::Int(1)));
        assert_eq!(v.get("b"), Some(&Value::str("x # not comment")));
    }

    #[test]
    fn flow_collections() {
        let v = parse("a: [1, 2, three]\nb: {x: 1, y: [true]}\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_seq().unwrap().len(), 3);
        assert_eq!(v.path(&["b", "x"]), Some(&Value::Int(1)));
        assert_eq!(v.path(&["b", "y"]).unwrap().as_seq().unwrap()[0], Value::Bool(true));
    }

    #[test]
    fn block_literal_strip_and_fold() {
        let v = parse("a: |-\n  x\n  y\nb: >\n  one\n  two\n").unwrap();
        assert_eq!(v.get("a"), Some(&Value::str("x\ny")));
        assert_eq!(v.get("b"), Some(&Value::str("one two\n")));
    }

    #[test]
    fn block_literal_keeps_inner_blank_lines() {
        let v = parse("s: |\n  l1\n\n  l3\n").unwrap();
        assert_eq!(v.get("s"), Some(&Value::str("l1\n\nl3\n")));
    }

    #[test]
    fn block_literal_with_comment_chars() {
        // PBS directives start with `#` — they are NOT comments inside a literal.
        let v = parse("batch: |\n  #PBS -l nodes=1\n  echo hi\n").unwrap();
        assert_eq!(v.get("batch"), Some(&Value::str("#PBS -l nodes=1\necho hi\n")));
    }

    #[test]
    fn multi_document() {
        let docs = parse_all("---\na: 1\n---\nb: 2\n").unwrap();
        assert_eq!(docs.len(), 2);
        assert_eq!(docs[1].get("b"), Some(&Value::Int(2)));
    }

    #[test]
    fn errors() {
        assert!(parse("a: 1\nb: 2\n").is_ok());
        assert!(parse("a: 1\na: 2\n").is_err(), "duplicate key");
        assert!(parse("a: &anchor x\n").is_err(), "anchor");
        assert!(parse("key 'no colon'\n").is_err());
    }

    #[test]
    fn quoted_strings() {
        let v = parse("a: \"line\\nbreak\"\nb: 'it''s'\n").unwrap();
        assert_eq!(v.get("a"), Some(&Value::str("line\nbreak")));
        assert_eq!(v.get("b"), Some(&Value::str("it's")));
    }

    #[test]
    fn emit_quotes_ambiguous_scalars() {
        let v = Value::map()
            .with("a", "true")
            .with("b", "123")
            .with("c", "- dash")
            .with("d", "plain");
        let y = to_string(&v);
        let back = parse(&y).unwrap();
        assert_eq!(back, v, "emitted:\n{y}");
    }

    #[test]
    fn deep_nesting_roundtrip() {
        let v = Value::map().with(
            "a",
            Value::Seq(vec![
                Value::map().with("b", Value::Seq(vec![Value::Int(1), Value::str("x y")])),
                Value::map().with("c", Value::map().with("d", Value::Null)),
            ]),
        );
        let y = to_string(&v);
        assert_eq!(parse(&y).unwrap(), v, "emitted:\n{y}");
    }

    #[test]
    fn empty_doc_is_null() {
        assert_eq!(parse("").unwrap(), Value::Null);
        assert_eq!(parse("# only comments\n").unwrap(), Value::Null);
    }
}
