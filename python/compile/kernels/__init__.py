"""L1: Pallas kernels for the containerised compute payloads."""

from . import attention, matmul_gelu, ref  # noqa: F401
