//! Versioned object store with watch streams — etcd + the API machinery's
//! watch cache, distilled.
//!
//! Every mutation bumps a global `resourceVersion`, is applied with
//! optimistic concurrency (update must carry the current version), is
//! committed through a [`StoreBackend`] (PR 6: append-on-commit
//! durability), and is appended to a bounded per-kind history so watchers
//! can replay from a version.
//!
//! # Sharding (PR 6)
//!
//! State is sharded **per kind** (the GVK axis of this API machinery):
//! each kind owns an independent lock, object map, watch history, and
//! watcher list. Reads — `get`, `list`, per-kind `watch`/`events_since`
//! — take only their shard's lock, so pod churn cannot stall node or
//! queue reads. Writes serialize through one global commit lock (the
//! moral equivalent of etcd's single raft log): that is what keeps
//! `resourceVersion` a single totally-ordered sequence across kinds,
//! which the cross-kind BOOKMARK frames of the streaming watch (PR 5)
//! rely on.
//!
//! Lock hierarchy (strictly outer → inner, no exceptions):
//! `global commit lock` → `shard map` → `individual shard`. Only a
//! global-lock holder may lock more than one shard. The current version
//! is mirrored in an atomic, stored while the written shard's lock is
//! still held — so any version a reader observes is already fully
//! committed (durable, in its shard's history, delivered to watchers).
//!
//! # Per-shard version contract
//!
//! - `resourceVersion`s are allocated from one global counter; a shard's
//!   history holds a (gapped) subsequence of it.
//! - [`Store::shard_version`] is the version of a kind's latest commit;
//!   `shard_version(k) <= current_version()` always.
//! - A per-kind watch from bookmark `b` replays exactly the events of
//!   that kind in `(b, now]`, or reports 410-Gone when `b` predates the
//!   shard's retained window. Other kinds' churn advances
//!   `current_version()` but can neither stall nor reset a shard's
//!   watch — it only surfaces as BOOKMARK frames.

use super::api::KubeObject;
use super::persist::{MemoryBackend, RecoveredState, Snapshot, StoreBackend, WalRecord};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::util::{Error, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Watch event types (mirrors the k8s watch API).
#[derive(Debug, Clone, PartialEq)]
pub enum WatchEvent {
    Added(KubeObject),
    Modified(KubeObject),
    Deleted(KubeObject),
}

impl WatchEvent {
    pub fn object(&self) -> &KubeObject {
        match self {
            WatchEvent::Added(o) | WatchEvent::Modified(o) | WatchEvent::Deleted(o) => o,
        }
    }

    /// The k8s wire tag for this event type.
    pub fn type_str(&self) -> &'static str {
        match self {
            WatchEvent::Added(_) => "ADDED",
            WatchEvent::Modified(_) => "MODIFIED",
            WatchEvent::Deleted(_) => "DELETED",
        }
    }

    /// Encode for the RPC transport: `{type, object}`.
    pub fn encode(&self) -> Value {
        Value::map().with("type", self.type_str()).with("object", self.object().encode())
    }

    pub fn decode(v: &Value) -> Result<WatchEvent> {
        let obj = KubeObject::decode(v.req("object")?)?;
        match v.req_str("type")? {
            "ADDED" => Ok(WatchEvent::Added(obj)),
            "MODIFIED" => Ok(WatchEvent::Modified(obj)),
            "DELETED" => Ok(WatchEvent::Deleted(obj)),
            other => Err(Error::parse(format!("unknown watch event type `{other}`"))),
        }
    }
}

/// Default watch-history window **per shard**. Small deployments never
/// notice it; a testbed expecting event bursts (every kubelet sync,
/// admission cycle, and autoscaler pass is a potential write) should size
/// it explicitly via [`Store::with_history_cap`] — a burst larger than
/// the window forces every watcher whose bookmark predates the trim into
/// a spurious relist (the 410-Gone path), which is exactly the
/// O(cluster) cost the informer layer exists to avoid. Since PR 6 the
/// window is per kind, so one kind's churn no longer evicts another
/// kind's history.
pub const DEFAULT_HISTORY_CAP: usize = 4096;

/// Global commit state: the version/uid counters, the durability
/// backend, and the all-kinds watcher list. Held for every write (writes
/// are serialized, like etcd's single log) and for all-kinds reads;
/// never for per-kind reads.
struct Global {
    version: u64,
    uid: u64,
    backend: Box<dyn StoreBackend>,
    /// Subscribers with `kind = None` — they observe the full commit
    /// sequence in order.
    watchers: Vec<Sender<WatchEvent>>,
}

/// Per-kind state. All per-kind reads lock only this.
struct Shard {
    /// name → object.
    objects: BTreeMap<String, KubeObject>,
    history: VecDeque<(u64, WatchEvent)>,
    /// Highest event version evicted from `history` (0 = nothing
    /// evicted). Replays from at or below this version may have lost
    /// events. Seeded with the recovery floor on WAL-recovered stores:
    /// pre-restart events below the last snapshot are unknowable.
    trimmed_through: u64,
    /// Version of this kind's latest commit.
    last_version: u64,
    watchers: Vec<Sender<WatchEvent>>,
}

impl Shard {
    fn new(floor: u64) -> Shard {
        Shard {
            objects: BTreeMap::new(),
            history: VecDeque::new(),
            trimmed_through: floor,
            last_version: 0,
            watchers: Vec::new(),
        }
    }
}

type ShardMap = BTreeMap<String, Arc<Mutex<Shard>>>;

/// The object store handle.
#[derive(Clone)]
pub struct Store {
    global: Arc<Mutex<Global>>,
    shards: Arc<Mutex<ShardMap>>,
    /// Mirror of `Global::version`, stored while the written shard's lock
    /// is still held — a lock-free `current_version()` that never runs
    /// ahead of commit visibility.
    version: Arc<AtomicU64>,
    history_cap: usize,
    /// Bookmarks below this predate what the backend recovered: fresh
    /// shards start their `trimmed_through` here.
    recovered_floor: u64,
    epoch: Instant,
    /// Store clock offset recovered from the backend (restart continuity
    /// for creation timestamps).
    base_s: f64,
    /// Commit-path latency sink (`kube.store.*` histograms). Defaults to
    /// a private registry; the ApiServer swaps in its own via
    /// [`Store::set_metrics`].
    metrics: Metrics,
}

impl Default for Store {
    fn default() -> Self {
        Self::new()
    }
}

impl Store {
    pub fn new() -> Store {
        Store::with_history_cap(DEFAULT_HISTORY_CAP)
    }

    /// A store with an explicit watch-history window (per shard). `cap`
    /// bounds how many events watchers (and the RPC watch poll) can
    /// replay before a stale bookmark turns into the 410-Gone reset;
    /// size it above the largest per-kind event burst expected between
    /// watcher polls.
    pub fn with_history_cap(cap: usize) -> Store {
        Store::with_backend(Box::new(MemoryBackend::new()), cap)
            .expect("memory backend cannot fail to load")
    }

    /// A store over an explicit durability backend. Recovers whatever the
    /// backend persisted: objects, version/uid counters, the store clock,
    /// and the WAL tail (which seeds the per-kind watch histories, so
    /// watchers reconnecting with pre-restart bookmarks replay deltas
    /// instead of resetting).
    pub fn with_backend(mut backend: Box<dyn StoreBackend>, cap: usize) -> Result<Store> {
        let recovered = backend.load()?;
        let cap = cap.max(1);
        let mut version = 0;
        let mut uid = 0;
        let mut base_s = 0.0;
        let mut floor = 0;
        let mut shards: ShardMap = BTreeMap::new();
        if let Some(RecoveredState { objects, version: v, uid: u, seconds, tail, tail_floor }) =
            recovered
        {
            version = v;
            uid = u;
            base_s = seconds;
            floor = tail_floor;
            for obj in objects {
                let sh = shards
                    .entry(obj.kind.clone())
                    .or_insert_with(|| Arc::new(Mutex::new(Shard::new(floor))));
                let mut sh = sh.lock().unwrap();
                sh.last_version = sh.last_version.max(obj.meta.resource_version);
                sh.objects.insert(obj.meta.name.clone(), obj);
            }
            for (ev_version, event) in tail {
                let sh = shards
                    .entry(event.object().kind.clone())
                    .or_insert_with(|| Arc::new(Mutex::new(Shard::new(floor))));
                let mut sh = sh.lock().unwrap();
                sh.history.push_back((ev_version, event));
                if sh.history.len() > cap {
                    if let Some((evicted, _)) = sh.history.pop_front() {
                        sh.trimmed_through = evicted;
                    }
                }
                sh.last_version = sh.last_version.max(ev_version);
            }
        }
        Ok(Store {
            global: Arc::new(Mutex::new(Global {
                version,
                uid,
                backend,
                watchers: Vec::new(),
            })),
            shards: Arc::new(Mutex::new(shards)),
            version: Arc::new(AtomicU64::new(version)),
            history_cap: cap,
            recovered_floor: floor,
            epoch: Instant::now(),
            base_s,
            metrics: Metrics::new(),
        })
    }

    /// Route commit-path histograms (`kube.store.commit_ns`,
    /// `kube.store.wal_append_ns`, `kube.store.fanout_ns`) into `m`
    /// instead of the store's private registry. Call before serving.
    pub fn set_metrics(&mut self, m: Metrics) {
        self.metrics = m;
    }

    /// The configured watch-history window (per shard).
    pub fn history_cap(&self) -> usize {
        self.history_cap
    }

    /// Seconds on the store clock (object creation timestamps). Continues
    /// across restarts when the backend recovered a clock.
    pub fn now_s(&self) -> f64 {
        self.base_s + self.epoch.elapsed().as_secs_f64()
    }

    /// The shard for `kind`, created on first touch. Locks only the shard
    /// map, and releases it before the caller locks the shard.
    fn shard(&self, kind: &str) -> Arc<Mutex<Shard>> {
        let mut map = self.shards.lock().unwrap();
        if let Some(sh) = map.get(kind) {
            return sh.clone();
        }
        let sh = Arc::new(Mutex::new(Shard::new(self.recovered_floor)));
        map.insert(kind.to_string(), sh.clone());
        sh
    }

    /// Snapshot the shard list (for all-kinds reads under the global
    /// lock).
    fn shard_list(&self) -> Vec<Arc<Mutex<Shard>>> {
        self.shards.lock().unwrap().values().cloned().collect()
    }

    /// Commit one mutation: durability append (abort on failure), counter
    /// bump, shard history + fan-out, atomic version publish. `g` is the
    /// held global lock; `sh` the held shard. Compaction is the caller's
    /// job (drop the shard lock first, then [`Store::maybe_compact`]).
    fn commit(
        &self,
        g: &mut Global,
        sh: &mut Shard,
        event: WatchEvent,
        bump_uid: bool,
        now: f64,
    ) -> Result<u64> {
        let t_commit = Instant::now();
        let v = g.version + 1;
        let uid = if bump_uid { g.uid + 1 } else { g.uid };
        let t_wal = Instant::now();
        g.backend.append(&WalRecord { version: v, uid, seconds: now, event: event.clone() })?;
        self.metrics.observe("kube.store.wal_append_ns", t_wal.elapsed().as_nanos() as u64);
        g.version = v;
        g.uid = uid;
        sh.history.push_back((v, event.clone()));
        if sh.history.len() > self.history_cap {
            if let Some((evicted, _)) = sh.history.pop_front() {
                sh.trimmed_through = evicted;
            }
        }
        sh.last_version = v;
        let t_fanout = Instant::now();
        sh.watchers.retain(|tx| tx.send(event.clone()).is_ok());
        g.watchers.retain(|tx| tx.send(event.clone()).is_ok());
        self.metrics.observe("kube.store.fanout_ns", t_fanout.elapsed().as_nanos() as u64);
        self.version.store(v, Ordering::Release);
        self.metrics.observe("kube.store.commit_ns", t_commit.elapsed().as_nanos() as u64);
        Ok(v)
    }

    /// Compact the backend if it asked for it. Must be called with the
    /// global lock held and NO shard lock held.
    fn maybe_compact(&self, g: &mut Global, now: f64) {
        if !g.backend.wants_compaction() {
            return;
        }
        let mut objects = Vec::new();
        for sh in self.shard_list() {
            let sh = sh.lock().unwrap();
            objects.extend(sh.objects.values().cloned());
        }
        let _ = g.backend.compact(&Snapshot {
            version: g.version,
            uid: g.uid,
            seconds: now,
            objects,
        });
    }

    /// Create; fails if (kind, name) exists. Returns the stored object
    /// (with uid/resourceVersion/creation assigned).
    pub fn create(&self, mut obj: KubeObject) -> Result<KubeObject> {
        let now = self.now_s();
        let mut g = self.global.lock().unwrap();
        let shard = self.shard(&obj.kind);
        let mut sh = shard.lock().unwrap();
        if sh.objects.contains_key(&obj.meta.name) {
            return Err(Error::already_exists(&obj.kind, &obj.meta.name));
        }
        obj.meta.uid = g.uid + 1;
        obj.meta.resource_version = g.version + 1;
        obj.meta.creation_s = now;
        sh.objects.insert(obj.meta.name.clone(), obj.clone());
        if let Err(e) = self.commit(&mut g, &mut sh, WatchEvent::Added(obj.clone()), true, now) {
            sh.objects.remove(&obj.meta.name);
            return Err(e);
        }
        drop(sh);
        self.maybe_compact(&mut g, now);
        Ok(obj)
    }

    pub fn get(&self, kind: &str, name: &str) -> Result<KubeObject> {
        self.shard(kind)
            .lock()
            .unwrap()
            .objects
            .get(name)
            .cloned()
            .ok_or_else(|| Error::not_found(kind, name))
    }

    /// Update with optimistic concurrency: `obj.meta.resource_version` must
    /// match the stored version.
    pub fn update(&self, mut obj: KubeObject) -> Result<KubeObject> {
        let now = self.now_s();
        let mut g = self.global.lock().unwrap();
        let shard = self.shard(&obj.kind);
        let mut sh = shard.lock().unwrap();
        let current = sh
            .objects
            .get(&obj.meta.name)
            .ok_or_else(|| Error::not_found(&obj.kind, &obj.meta.name))?;
        if current.meta.resource_version != obj.meta.resource_version {
            return Err(Error::conflict(&obj.kind, &obj.meta.name));
        }
        obj.meta.uid = current.meta.uid;
        obj.meta.creation_s = current.meta.creation_s;
        obj.meta.resource_version = g.version + 1;
        let prev = sh.objects.insert(obj.meta.name.clone(), obj.clone());
        if let Err(e) =
            self.commit(&mut g, &mut sh, WatchEvent::Modified(obj.clone()), false, now)
        {
            if let Some(prev) = prev {
                sh.objects.insert(obj.meta.name.clone(), prev);
            }
            return Err(e);
        }
        drop(sh);
        self.maybe_compact(&mut g, now);
        Ok(obj)
    }

    /// Batched read-modify-write (PR 9): apply `mutate(i, obj)` to each
    /// named object and commit, all under ONE global-lock section — per
    /// the lock hierarchy the global holder may take shard locks one at
    /// a time, so a batch even spanning kinds works. No concurrent
    /// writer can interleave between items, which is what turns the
    /// scheduler's N binds into one conflict-free commit burst instead
    /// of N racing read-modify-write loops. Per-item errors (NotFound, a
    /// failed WAL append) surface in that item's slot without poisoning
    /// the rest of the batch.
    pub fn update_batch(
        &self,
        keys: &[(String, String)],
        mutate: &dyn Fn(usize, &mut KubeObject),
    ) -> Vec<Result<KubeObject>> {
        let now = self.now_s();
        let mut g = self.global.lock().unwrap();
        let mut out = Vec::with_capacity(keys.len());
        for (i, (kind, name)) in keys.iter().enumerate() {
            let shard = self.shard(kind);
            let mut sh = shard.lock().unwrap();
            let Some(current) = sh.objects.get(name).cloned() else {
                out.push(Err(Error::not_found(kind, name)));
                continue;
            };
            let mut obj = current.clone();
            mutate(i, &mut obj);
            // Identity fields are server-owned, exactly as in update().
            obj.meta.uid = current.meta.uid;
            obj.meta.creation_s = current.meta.creation_s;
            obj.meta.resource_version = g.version + 1;
            sh.objects.insert(obj.meta.name.clone(), obj.clone());
            match self.commit(&mut g, &mut sh, WatchEvent::Modified(obj.clone()), false, now) {
                Ok(_) => out.push(Ok(obj)),
                Err(e) => {
                    sh.objects.insert(name.clone(), current);
                    out.push(Err(e));
                }
            }
        }
        // Shard locks are all released (per-iteration scope); compaction
        // needs the global lock only.
        self.maybe_compact(&mut g, now);
        out
    }

    pub fn delete(&self, kind: &str, name: &str) -> Result<KubeObject> {
        let now = self.now_s();
        let mut g = self.global.lock().unwrap();
        let shard = self.shard(kind);
        let mut sh = shard.lock().unwrap();
        let obj = sh.objects.remove(name).ok_or_else(|| Error::not_found(kind, name))?;
        if let Err(e) =
            self.commit(&mut g, &mut sh, WatchEvent::Deleted(obj.clone()), false, now)
        {
            sh.objects.insert(name.to_string(), obj);
            return Err(e);
        }
        drop(sh);
        self.maybe_compact(&mut g, now);
        Ok(obj)
    }

    /// List objects of a kind, optionally filtered by a label selector
    /// (all pairs must match). Locks only the kind's shard.
    pub fn list(&self, kind: &str, selector: &[(String, String)]) -> Vec<KubeObject> {
        self.shard(kind)
            .lock()
            .unwrap()
            .objects
            .values()
            .filter(|o| selector.iter().all(|(k, v)| o.meta.label(k) == Some(v.as_str())))
            .cloned()
            .collect()
    }

    /// All objects of all kinds — a consistent cross-kind snapshot (takes
    /// the global lock, so commits are parked while it images the
    /// shards).
    pub fn list_all(&self) -> Vec<KubeObject> {
        let _g = self.global.lock().unwrap();
        let mut out = Vec::new();
        for sh in self.shard_list() {
            out.extend(sh.lock().unwrap().objects.values().cloned());
        }
        out
    }

    pub fn current_version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Version of `kind`'s latest commit (0 = no commit yet). Always
    /// `<= current_version()`; the gap is other kinds' churn.
    pub fn shard_version(&self, kind: &str) -> u64 {
        self.shard(kind).lock().unwrap().last_version
    }

    /// Highest event version evicted from any shard's watch history (0 =
    /// nothing evicted yet). A cross-kind watch bookmark at or below this
    /// is stale: replaying from it may silently miss events.
    pub fn trimmed_through(&self) -> u64 {
        let _g = self.global.lock().unwrap();
        let mut t = self.recovered_floor;
        for sh in self.shard_list() {
            t = t.max(sh.lock().unwrap().trimmed_through);
        }
        t
    }

    /// Watch events for `kind` (None = all kinds) from `from_version`
    /// (exclusive). Replays history first, then streams live events. A
    /// bookmark older than the retained window cannot be replayed
    /// faithfully: the returned stream is already ended (no watcher
    /// registered) — the 410-Gone signal — so the caller relists and
    /// rewatches. The staleness check happens under the same lock as the
    /// replay + registration, so it cannot race a concurrent trim.
    pub fn watch(&self, kind: Option<&str>, from_version: u64) -> Receiver<WatchEvent> {
        match self.try_watch(kind, from_version) {
            (_, Some(rx)) => rx,
            (_, None) => channel().1, // tx dropped: ended stream (410)
        }
    }

    /// Watch with an explicit 410 verdict: `None` when `from_version` has
    /// fallen out of the retained history window (the caller must relist
    /// instead of trusting a replay), otherwise the replay-then-live
    /// receiver of [`Store::watch`]. Also returns the store version at
    /// registration — the stream's starting bookmark. The staleness
    /// check, the replay, and the registration all happen under one lock
    /// (the shard's for per-kind watches, the global for all-kinds), so
    /// they cannot race a concurrent trim.
    pub fn try_watch(
        &self,
        kind: Option<&str>,
        from_version: u64,
    ) -> (u64, Option<Receiver<WatchEvent>>) {
        let (tx, rx) = channel();
        match kind {
            Some(k) => {
                let shard = self.shard(k);
                let mut sh = shard.lock().unwrap();
                if from_version < sh.trimmed_through {
                    return (self.current_version(), None);
                }
                for (v, ev) in sh.history.iter() {
                    if *v > from_version {
                        let _ = tx.send(ev.clone());
                    }
                }
                sh.watchers.push(tx);
                // Loaded under the shard lock: every event of this kind
                // at or below it is replayed above or will arrive live.
                (self.current_version(), Some(rx))
            }
            None => {
                let mut g = self.global.lock().unwrap();
                let (version, events, reset) = self.merged_events(&g, from_version);
                if reset {
                    return (version, None);
                }
                for ev in events {
                    let _ = tx.send(ev);
                }
                g.watchers.push(tx);
                (version, Some(rx))
            }
        }
    }

    /// One-shot replay: events for `kind` (None = all) newer than
    /// `from_version`, plus the store version they bring the caller up to,
    /// plus a `reset` flag. This is the poll primitive behind the RPC
    /// transport's watch — and, per kind, the delta-relist primitive (PR
    /// 6) — no watcher is registered, so it is safe to call at any rate.
    /// `reset = true` means `from_version` has fallen out of the retained
    /// history window, so events may have been lost — the 410-Gone signal
    /// of the k8s watch API; the caller must relist and rewatch rather
    /// than trust the replay.
    pub fn events_since(
        &self,
        kind: Option<&str>,
        from_version: u64,
    ) -> (u64, Vec<WatchEvent>, bool) {
        match kind {
            Some(k) => {
                let shard = self.shard(k);
                let sh = shard.lock().unwrap();
                let reset = from_version < sh.trimmed_through;
                let events = sh
                    .history
                    .iter()
                    .filter(|(v, _)| *v > from_version)
                    .map(|(_, ev)| ev.clone())
                    .collect();
                // Loaded under the shard lock, so no event of this kind
                // at or below the returned version can be missing.
                (self.current_version(), events, reset)
            }
            None => {
                let g = self.global.lock().unwrap();
                let (version, events, reset) = self.merged_events(&g, from_version);
                (version, events.into_iter().map(|(_, ev)| ev).collect(), reset)
            }
        }
    }

    /// Merge every shard's history above `from_version`, in commit order.
    /// Caller holds the global lock (`_g`), so no commit can interleave.
    fn merged_events(
        &self,
        g: &Global,
        from_version: u64,
    ) -> (u64, Vec<(u64, WatchEvent)>, bool) {
        let mut reset = from_version < self.recovered_floor;
        let mut events: Vec<(u64, WatchEvent)> = Vec::new();
        for sh in self.shard_list() {
            let sh = sh.lock().unwrap();
            if from_version < sh.trimmed_through {
                reset = true;
            }
            events.extend(
                sh.history.iter().filter(|(v, _)| *v > from_version).cloned(),
            );
        }
        events.sort_by_key(|(v, _)| *v);
        (g.version, events, reset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::Value;
    use crate::kube::api::KIND_POD;
    use crate::kube::persist::WalBackend;

    fn pod(name: &str) -> KubeObject {
        KubeObject::new(KIND_POD, name, Value::map().with("x", 1i64))
    }

    #[test]
    fn create_get_delete() {
        let s = Store::new();
        let stored = s.create(pod("a")).unwrap();
        assert_eq!(stored.meta.uid, 1);
        assert!(stored.meta.resource_version > 0);
        assert!(s.create(pod("a")).is_err(), "duplicate");
        assert_eq!(s.get(KIND_POD, "a").unwrap().meta.uid, 1);
        s.delete(KIND_POD, "a").unwrap();
        assert!(s.get(KIND_POD, "a").unwrap_err().is_not_found());
        assert!(s.delete(KIND_POD, "a").is_err());
    }

    #[test]
    fn optimistic_concurrency() {
        let s = Store::new();
        let a = s.create(pod("a")).unwrap();
        let mut fresh = a.clone();
        fresh.spec.insert("x", 2i64);
        let updated = s.update(fresh).unwrap();
        assert!(updated.meta.resource_version > a.meta.resource_version);
        // Updating with the stale version conflicts.
        let mut stale = a;
        stale.spec.insert("x", 3i64);
        assert!(s.update(stale).unwrap_err().is_conflict());
    }

    #[test]
    fn list_with_selector() {
        let s = Store::new();
        let mut a = pod("a");
        a.meta.set_label("app", "web");
        let mut b = pod("b");
        b.meta.set_label("app", "db");
        s.create(a).unwrap();
        s.create(b).unwrap();
        s.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
        assert_eq!(s.list(KIND_POD, &[]).len(), 2);
        let sel = vec![("app".to_string(), "web".to_string())];
        let filtered = s.list(KIND_POD, &sel);
        assert_eq!(filtered.len(), 1);
        assert_eq!(filtered[0].meta.name, "a");
        assert_eq!(s.list("Node", &[]).len(), 1);
    }

    #[test]
    fn watch_receives_live_events() {
        let s = Store::new();
        let rx = s.watch(Some(KIND_POD), s.current_version());
        s.create(pod("a")).unwrap();
        let mut a2 = s.get(KIND_POD, "a").unwrap();
        a2.status = Value::map().with("phase", "Running");
        s.update(a2).unwrap();
        s.delete(KIND_POD, "a").unwrap();
        let events: Vec<WatchEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 3);
        assert!(matches!(events[0], WatchEvent::Added(_)));
        assert!(matches!(events[1], WatchEvent::Modified(_)));
        assert!(matches!(events[2], WatchEvent::Deleted(_)));
    }

    #[test]
    fn watch_replays_history_from_version() {
        let s = Store::new();
        s.create(pod("a")).unwrap();
        let v = s.current_version();
        s.create(pod("b")).unwrap();
        let rx = s.watch(Some(KIND_POD), v);
        let events: Vec<WatchEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 1, "only b replayed");
        assert_eq!(events[0].object().meta.name, "b");
    }

    #[test]
    fn watch_filters_kind() {
        let s = Store::new();
        let rx = s.watch(Some("Node"), 0);
        s.create(pod("a")).unwrap();
        s.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
        let events: Vec<WatchEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].object().kind, "Node");
    }

    #[test]
    fn events_since_replays_without_subscribing() {
        let s = Store::new();
        s.create(pod("a")).unwrap();
        let v = s.current_version();
        s.create(pod("b")).unwrap();
        s.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
        let (rv, events, reset) = s.events_since(Some(KIND_POD), v);
        assert_eq!(rv, s.current_version());
        assert!(!reset);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].object().meta.name, "b");
        // All kinds, from the beginning.
        let (_, all, _) = s.events_since(None, 0);
        assert_eq!(all.len(), 3);
        // Cross-kind merge preserves commit order.
        assert_eq!(all[0].object().meta.name, "a");
        assert_eq!(all[1].object().meta.name, "b");
        assert_eq!(all[2].object().kind, "Node");
        // Caught up: nothing new.
        let (rv2, none, reset) = s.events_since(None, rv);
        assert_eq!(rv2, rv);
        assert!(none.is_empty());
        assert!(!reset);
    }

    #[test]
    fn watch_with_stale_bookmark_returns_ended_stream() {
        let s = Store::new();
        let first = s.create(pod("seed")).unwrap().meta.resource_version;
        for i in 0..DEFAULT_HISTORY_CAP + 8 {
            let mut o = s.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            s.update(o).unwrap();
        }
        let rx = s.watch(Some(KIND_POD), first);
        assert!(
            matches!(rx.try_recv(), Err(std::sync::mpsc::TryRecvError::Disconnected)),
            "stale bookmark must get the 410-Gone ended stream"
        );
        // A fresh bookmark still gets a live stream.
        let rx = s.watch(Some(KIND_POD), s.current_version());
        s.create(pod("later")).unwrap();
        assert_eq!(rx.try_iter().count(), 1);
    }

    #[test]
    fn try_watch_reports_gone_explicitly() {
        let s = Store::new();
        let first = s.create(pod("seed")).unwrap().meta.resource_version;
        for i in 0..DEFAULT_HISTORY_CAP + 8 {
            let mut o = s.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            s.update(o).unwrap();
        }
        // Stale bookmark: an explicit None (the streaming RPC path turns
        // this into a `gone` StreamEnd), with the current version so the
        // caller can relist from it.
        let (rv, maybe) = s.try_watch(Some(KIND_POD), first);
        assert_eq!(rv, s.current_version());
        assert!(maybe.is_none(), "stale bookmark must be an explicit 410");
        // Fresh bookmark: a live stream.
        let (rv2, live) = s.try_watch(Some(KIND_POD), s.current_version());
        assert_eq!(rv2, s.current_version());
        let live = live.unwrap();
        s.create(pod("later")).unwrap();
        assert_eq!(live.try_iter().count(), 1);
    }

    #[test]
    fn events_since_signals_reset_past_history_window() {
        let s = Store::new();
        let first = s.create(pod("seed")).unwrap().meta.resource_version;
        // Push enough writes to evict the seed event from history.
        for i in 0..DEFAULT_HISTORY_CAP + 8 {
            let mut o = s.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            s.update(o).unwrap();
        }
        let (_, _, reset) = s.events_since(None, first);
        assert!(reset, "bookmark older than the window must signal reset");
        let (rv, events, reset) = s.events_since(None, s.current_version() - 1);
        assert!(!reset, "fresh bookmark replays normally");
        assert_eq!(events.len(), 1);
        assert_eq!(rv, s.current_version());
    }

    /// Regression (ISSUE 4 satellite): the watch-history window used to be
    /// a hardcoded 4096 — an event burst larger than that between two
    /// watch polls trimmed the bookmark out of history and forced a
    /// spurious relist. A store sized above the burst replays it cleanly.
    #[test]
    fn sized_history_window_survives_burst_that_overflows_old_default() {
        let burst = DEFAULT_HISTORY_CAP + 100;
        // Old default: the burst trims the bookmark out of the window.
        let small = Store::new();
        let bookmark = small.create(pod("seed")).unwrap().meta.resource_version;
        for i in 0..burst {
            let mut o = small.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            small.update(o).unwrap();
        }
        let (_, _, reset) = small.events_since(None, bookmark);
        assert!(reset, "old default window loses a {burst}-event burst");

        // Sized window: the same burst replays without a reset.
        let big = Store::with_history_cap(2 * DEFAULT_HISTORY_CAP);
        assert_eq!(big.history_cap(), 2 * DEFAULT_HISTORY_CAP);
        let bookmark = big.create(pod("seed")).unwrap().meta.resource_version;
        for i in 0..burst {
            let mut o = big.get(KIND_POD, "seed").unwrap();
            o.status.insert("n", i as u64);
            big.update(o).unwrap();
        }
        let (rv, events, reset) = big.events_since(None, bookmark);
        assert!(!reset, "sized window must absorb the burst");
        assert_eq!(events.len(), burst);
        assert_eq!(rv, big.current_version());
    }

    #[test]
    fn watch_event_wire_roundtrip() {
        let s = Store::new();
        let o = s.create(pod("a")).unwrap();
        for ev in [
            WatchEvent::Added(o.clone()),
            WatchEvent::Modified(o.clone()),
            WatchEvent::Deleted(o),
        ] {
            let back = WatchEvent::decode(&ev.encode()).unwrap();
            assert_eq!(back, ev);
        }
        assert!(WatchEvent::decode(&Value::map().with("type", "BOGUS")).is_err());
    }

    #[test]
    fn update_preserves_identity() {
        let s = Store::new();
        let a = s.create(pod("a")).unwrap();
        let mut mod_a = a.clone();
        mod_a.meta.uid = 999; // attempts to forge identity are ignored
        mod_a.meta.creation_s = -1.0;
        let updated = s.update(mod_a).unwrap();
        assert_eq!(updated.meta.uid, a.meta.uid);
        assert_eq!(updated.meta.creation_s, a.meta.creation_s);
    }

    // ---- PR 6: sharding + durability ---------------------------------

    /// The per-shard version contract: one global sequence, per-kind
    /// subsequences; another kind's churn past a shard's history cap
    /// neither resets nor pollutes a per-kind watch.
    #[test]
    fn shard_isolation_survives_foreign_kind_churn() {
        let s = Store::with_history_cap(64);
        let n = s.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
        let node_v = n.meta.resource_version;
        // Churn pods far past the history window.
        s.create(pod("p")).unwrap();
        for i in 0..200 {
            let mut o = s.get(KIND_POD, "p").unwrap();
            o.status.insert("n", i as u64);
            s.update(o).unwrap();
        }
        assert_eq!(s.shard_version("Node"), node_v, "pod churn leaves the node shard alone");
        assert!(s.shard_version(KIND_POD) > node_v);
        assert!(s.shard_version(KIND_POD) <= s.current_version());
        // A node watch from the pre-churn bookmark replays cleanly: no
        // reset, no pod events.
        let (rv, events, reset) = s.events_since(Some("Node"), node_v);
        assert!(!reset, "foreign churn must not trim the node shard");
        assert!(events.is_empty());
        assert_eq!(rv, s.current_version());
        // Whereas the pod shard itself did trim.
        let (_, _, reset) = s.events_since(Some(KIND_POD), node_v);
        assert!(reset, "the churned shard trims normally");
    }

    #[test]
    fn wal_store_recovers_objects_versions_and_clock() {
        let dir = std::env::temp_dir()
            .join(format!("hpcorc-store-wal-{}-recover", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (version, uid, creation) = {
            let s = Store::with_backend(
                Box::new(WalBackend::open(&dir).unwrap()),
                DEFAULT_HISTORY_CAP,
            )
            .unwrap();
            let a = s.create(pod("a")).unwrap();
            let mut a2 = a.clone();
            a2.status = Value::map().with("phase", "Running");
            s.update(a2).unwrap();
            s.create(pod("gone")).unwrap();
            s.delete(KIND_POD, "gone").unwrap();
            s.create(KubeObject::new("Node", "n1", Value::map())).unwrap();
            (s.current_version(), a.meta.uid, a.meta.creation_s)
        };

        let s2 = Store::with_backend(
            Box::new(WalBackend::open(&dir).unwrap()),
            DEFAULT_HISTORY_CAP,
        )
        .unwrap();
        assert_eq!(s2.current_version(), version, "version counter survives");
        let a = s2.get(KIND_POD, "a").unwrap();
        assert_eq!(a.meta.uid, uid, "uid survives");
        assert_eq!(a.meta.creation_s, creation, "creation timestamp survives");
        assert_eq!(a.status.opt_str("phase"), Some("Running"));
        assert!(s2.get(KIND_POD, "gone").unwrap_err().is_not_found());
        assert_eq!(s2.list("Node", &[]).len(), 1);
        assert!(s2.now_s() >= creation, "store clock continues, never rewinds");
        // New writes continue the version sequence without collisions.
        let b = s2.create(pod("b")).unwrap();
        assert!(b.meta.resource_version > version);
        assert!(b.meta.uid > uid);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A recovered store can serve *delta* replays to watchers whose
    /// bookmarks predate the restart: the WAL tail seeds the shard
    /// histories.
    #[test]
    fn wal_store_replays_pre_restart_bookmarks_without_reset() {
        let dir = std::env::temp_dir()
            .join(format!("hpcorc-store-wal-{}-tail", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let bookmark = {
            let s = Store::with_backend(
                Box::new(WalBackend::open(&dir).unwrap()),
                DEFAULT_HISTORY_CAP,
            )
            .unwrap();
            s.create(pod("a")).unwrap();
            let bookmark = s.current_version();
            s.create(pod("b")).unwrap();
            s.create(pod("c")).unwrap();
            bookmark
        };
        let s2 = Store::with_backend(
            Box::new(WalBackend::open(&dir).unwrap()),
            DEFAULT_HISTORY_CAP,
        )
        .unwrap();
        let (rv, events, reset) = s2.events_since(Some(KIND_POD), bookmark);
        assert!(!reset, "pre-restart bookmark replays from the recovered tail");
        assert_eq!(events.len(), 2, "only b and c: a delta, not a full relist");
        assert_eq!(events[0].object().meta.name, "b");
        assert_eq!(events[1].object().meta.name, "c");
        assert_eq!(rv, s2.current_version());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Compaction (snapshot + log truncate) keeps recovery exact and
    /// resets the replayable floor: bookmarks below the snapshot reset.
    #[test]
    fn wal_store_compaction_preserves_state_and_floors_bookmarks() {
        let dir = std::env::temp_dir()
            .join(format!("hpcorc-store-wal-{}-compact", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let (version, early) = {
            let s = Store::with_backend(
                Box::new(WalBackend::open(&dir).unwrap().with_compact_threshold(8)),
                DEFAULT_HISTORY_CAP,
            )
            .unwrap();
            let early = s.create(pod("a")).unwrap().meta.resource_version;
            for i in 0..20 {
                let mut o = s.get(KIND_POD, "a").unwrap();
                o.status.insert("n", i as u64);
                s.update(o).unwrap();
            }
            (s.current_version(), early)
        };
        assert!(
            std::fs::read_to_string(dir.join("snapshot.json")).unwrap().contains("\"a\""),
            "compaction wrote a snapshot"
        );
        let s2 = Store::with_backend(
            Box::new(WalBackend::open(&dir).unwrap()),
            DEFAULT_HISTORY_CAP,
        )
        .unwrap();
        assert_eq!(s2.current_version(), version);
        assert_eq!(s2.list(KIND_POD, &[]).len(), 1);
        // A bookmark from before the snapshot cannot be served as a
        // delta: explicit reset, not a silent miss.
        let (_, _, reset) = s2.events_since(Some(KIND_POD), early);
        assert!(reset, "pre-snapshot bookmark must reset");
        // Fresh shards inherit the floor too: a kind never seen since
        // the snapshot resets rather than replaying emptily.
        let (_, _, reset) = s2.events_since(Some("Ghost"), early);
        assert!(reset, "unseen-kind bookmark below the floor must reset");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A failed durability append aborts the commit: no version bump, no
    /// watch event, no state change.
    #[test]
    fn failed_append_aborts_commit() {
        struct FailingBackend {
            fail: std::sync::Arc<std::sync::atomic::AtomicBool>,
        }
        impl StoreBackend for FailingBackend {
            fn load(&mut self) -> Result<Option<RecoveredState>> {
                Ok(None)
            }
            fn append(&mut self, _r: &WalRecord) -> Result<()> {
                if self.fail.load(Ordering::Relaxed) {
                    Err(Error::internal("disk full"))
                } else {
                    Ok(())
                }
            }
        }
        let fail = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let s = Store::with_backend(
            Box::new(FailingBackend { fail: fail.clone() }),
            DEFAULT_HISTORY_CAP,
        )
        .unwrap();
        let a = s.create(pod("a")).unwrap();
        let rx = s.watch(Some(KIND_POD), s.current_version());
        let v = s.current_version();
        fail.store(true, Ordering::Relaxed);
        assert!(s.create(pod("b")).is_err());
        let mut a2 = a.clone();
        a2.status.insert("phase", "Running");
        assert!(s.update(a2.clone()).is_err());
        assert!(s.delete(KIND_POD, "a").is_err());
        assert_eq!(s.current_version(), v, "no version bump on failed append");
        assert!(s.get(KIND_POD, "b").unwrap_err().is_not_found());
        assert_eq!(s.get(KIND_POD, "a").unwrap(), a, "update rolled back");
        assert_eq!(rx.try_iter().count(), 0, "no watch event leaked");
        // Recovered backend: commits flow again and versions resume.
        fail.store(false, Ordering::Relaxed);
        let b = s.create(pod("b")).unwrap();
        assert_eq!(b.meta.resource_version, v + 1);
        assert_eq!(rx.try_iter().count(), 1);
    }
}
