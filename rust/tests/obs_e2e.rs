//! Observability end-to-end (PR 7 acceptance): one pod driven through
//! create → kueue-admit → schedule → bind over the red-box testbed must
//! yield ONE connected causal trace — rooted at the client's span,
//! joined by the API server, the admission controller, and the
//! scheduler — exportable as valid Chrome trace-event JSON, with the
//! create→bound SLO histogram scrapeable remotely in Prometheus text.

use hpcorc::cluster::Resources;
use hpcorc::encoding::{json, Value};
use hpcorc::hybrid::{Testbed, TestbedConfig};
use hpcorc::kube::{ApiClient, EventView, ListOptions, PodView, RemoteApi, KIND_EVENT, KIND_POD};
use hpcorc::kueue::{ClusterQueueView, LocalQueueView, QueueResources};
use hpcorc::obs;
use hpcorc::redbox::RedboxClient;
use hpcorc::singularity::{Payload, SifImage};
use std::time::{Duration, Instant};

#[test]
fn pod_lifecycle_yields_one_connected_trace_and_remote_slo_histogram() {
    let tb = Testbed::start(TestbedConfig::default()).expect("testbed");
    let remote = RemoteApi::connect(tb.socket()).expect("remote client");

    // Queue topology first, so the admission controller has somewhere to
    // admit the pod into.
    remote
        .create(ClusterQueueView::build("e2e-cq", QueueResources::nodes(4)))
        .expect("cluster queue");
    remote.create(LocalQueueView::build("e2e-team", "e2e-cq")).expect("local queue");

    // The traced create: a client-side root span, exactly like the CLI's
    // `kubectl apply`. The trace id must survive the wire, the store, and
    // every control loop downstream.
    let root = {
        let guard = obs::span("e2e-test", "create traced pod");
        let root = guard.context().expect("tracing on by default");
        let mut p = PodView::build("e2e-pod", "img.sif", Resources::new(100, 1 << 20, 0), &[]);
        hpcorc::kueue::queue_workload(&mut p, "e2e-team");
        remote.create(p).expect("create pod");
        root
    };

    // Wait for the full admit → schedule → bind chain.
    let deadline = Instant::now() + Duration::from_secs(30);
    let bound = loop {
        let obj = remote.get(KIND_POD, "e2e-pod").expect("get pod");
        if obj.spec.opt_str("nodeName").is_some() {
            break obj;
        }
        assert!(Instant::now() < deadline, "pod never bound");
        std::thread::sleep(Duration::from_millis(5));
    };

    // -- the annotation carries the caller's trace -----------------------
    let wire = bound
        .meta
        .annotation(obs::TRACE_ANNOTATION)
        .expect("bound pod keeps hpcorc.io/trace");
    let ctx = obs::TraceContext::parse_wire(wire).expect("well-formed trace annotation");
    assert_eq!(ctx.trace_id, root.trace_id, "object joined a different trace");
    let trace_hex = format!("{:016x}", ctx.trace_id);

    // -- one connected tree, visible through the remote span service -----
    // Bind/admit spans land in the ring moments after the status write
    // becomes readable; poll briefly instead of racing them.
    let rpc = RedboxClient::connect(tb.socket()).expect("rpc client");
    let deadline = Instant::now() + Duration::from_secs(10);
    let events: Vec<Value> = loop {
        let out = rpc
            .call("obs.Spans/ByTrace", Value::map().with("trace", trace_hex.clone()))
            .expect("ByTrace");
        let events = out.get("events").and_then(Value::as_seq).unwrap_or(&[]).to_vec();
        let cats: Vec<&str> =
            events.iter().filter_map(|e| e.opt_str("cat")).collect();
        if ["apiserver", "kueue", "kube-sched"].iter().all(|c| cats.contains(c)) {
            break events;
        }
        assert!(
            Instant::now() < deadline,
            "trace never connected across components; saw {cats:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    for e in &events {
        assert_eq!(
            e.get("args").and_then(|a| a.opt_str("trace_id")),
            Some(trace_hex.as_str()),
            "every exported span belongs to the one trace"
        );
    }
    // The remote create dispatched through the red-box server under the
    // same trace (wire-context adoption).
    assert!(
        events.iter().any(|e| e.opt_str("cat") == Some("redbox-server")),
        "server dispatch spans join the caller's trace"
    );

    // -- valid Chrome trace-event JSON (Perfetto-loadable) ---------------
    let spans = obs::by_trace(ctx.trace_id);
    assert!(spans.len() >= 4, "expected a multi-component tree, got {}", spans.len());
    let chrome = obs::chrome_json(&spans);
    let parsed = json::parse(&chrome).expect("chrome export is valid JSON");
    let arr = parsed.as_seq().expect("chrome export is a JSON array");
    assert_eq!(arr.len(), spans.len());
    for ev in arr {
        assert_eq!(ev.opt_str("ph"), Some("X"), "complete-event format");
        assert!(ev.opt_int("ts").is_some() && ev.opt_int("dur").is_some());
    }

    // -- the SLO histogram is scrapeable remotely in Prometheus text -----
    let prom = rpc.call("obs.Metrics/Prom", Value::Null).expect("Prom scrape");
    let text = prom.opt_str("text").expect("text body");
    assert!(
        text.contains("# TYPE slo_pod_create_to_bound_ns histogram"),
        "create->bound SLO histogram must be exposed"
    );
    assert!(text.contains("slo_pod_create_to_bound_ns_count 1"), "exactly the one e2e pod");
    assert!(text.contains("slo_pod_create_to_bound_ns_bucket{le=\"+Inf\"} 1"));
    // The commit path instrumentation fired too.
    assert!(text.contains("# TYPE kube_store_commit_ns histogram"));
    assert!(text.contains("# TYPE redbox_handle_ns histogram"));

    tb.stop();
}

/// PR 8 acceptance: one pod lifecycle over the socket yields (a) ≥4
/// cluster events from ≥3 distinct components, every one carrying the
/// pod's trace id; (b) an audit trail of the mutating requests, actor-
/// and trace-attributed; (c) a Prometheus scrape with real labelled
/// metric families. All three views agree on the same trace.
#[test]
fn pod_lifecycle_yields_events_audit_and_labelled_metrics() {
    let tb = Testbed::start(TestbedConfig::default()).expect("testbed");
    // A payload long enough to observe Running, short enough to not
    // outlive the test (nominal ms × time_scale 0.001 ≈ 3s real).
    tb.images.push(SifImage::new("obs-sleep.sif", Payload::Sleep { millis: 3_000_000 }));
    let remote = RemoteApi::connect(tb.socket()).expect("remote client");
    remote
        .create(ClusterQueueView::build("obs-cq", QueueResources::nodes(4)))
        .expect("cluster queue");
    remote.create(LocalQueueView::build("obs-team", "obs-cq")).expect("local queue");

    // Traced + attributed create, exactly like `kubectl apply`.
    let root = {
        let _actor = obs::push_actor("e2e-test");
        let guard = obs::span("e2e-test", "create traced pod");
        let root = guard.context().expect("tracing on by default");
        let mut p =
            PodView::build("obs-pod", "obs-sleep.sif", Resources::new(100, 1 << 20, 0), &[]);
        hpcorc::kueue::queue_workload(&mut p, "obs-team");
        remote.create(p).expect("create pod");
        root
    };
    let trace_hex = format!("{:016x}", root.trace_id);

    // Admit → schedule → start: wait for Running, then for the full
    // event fan (kueue + scheduler + kubelet all write asynchronously).
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let obj = remote.get(KIND_POD, "obs-pod").expect("get pod");
        if obj.status.opt_str("phase") == Some("Running") {
            break;
        }
        assert!(Instant::now() < deadline, "pod never ran");
        std::thread::sleep(Duration::from_millis(5));
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    let evs: Vec<EventView> = loop {
        let evs: Vec<EventView> = remote
            .list(KIND_EVENT, &ListOptions::all())
            .expect("list events")
            .items
            .iter()
            .filter_map(|o| EventView::from_object(o).ok())
            .filter(|e| e.regarding_kind == KIND_POD && e.regarding_name == "obs-pod")
            .collect();
        let mut components: Vec<&str> =
            evs.iter().map(|e| e.reporting_controller.as_str()).collect();
        components.sort();
        components.dedup();
        if evs.len() >= 4 && components.len() >= 3 {
            break evs;
        }
        assert!(
            Instant::now() < deadline,
            "event fan never completed: {:?}",
            evs.iter().map(|e| format!("{}/{}", e.reporting_controller, e.reason)).collect::<Vec<_>>()
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    for e in &evs {
        assert_eq!(
            e.trace_id(),
            Some(trace_hex.as_str()),
            "event {} from {} must carry the pod's trace",
            e.reason,
            e.reporting_controller
        );
    }
    for reason in ["Admitted", "Scheduled", "Started"] {
        assert!(evs.iter().any(|e| e.reason == reason), "missing event {reason}");
    }

    // -- the audit trail attributes the mutating requests ----------------
    let rpc = RedboxClient::connect(tb.socket()).expect("rpc client");
    let audit = rpc
        .call("obs.Audit/Query", Value::map().with("kind", KIND_POD))
        .expect("Audit query");
    let records = audit.get("records").and_then(Value::as_seq).unwrap_or(&[]).to_vec();
    let create = records
        .iter()
        .find(|r| r.opt_str("verb") == Some("create") && r.opt_str("name") == Some("obs-pod"))
        .expect("pod create audited");
    assert_eq!(create.opt_str("actor"), Some("e2e-test"), "actor rides the red-box envelope");
    assert_eq!(create.opt_str("trace"), Some(trace_hex.as_str()));
    assert_eq!(create.opt_str("outcome"), Some("ok"));
    // The scheduler's bind is attributed to its component and joined the
    // same trace (origin-trace adoption).
    assert!(
        records.iter().any(|r| r.opt_str("actor") == Some("kube-scheduler")
            && r.opt_str("name") == Some("obs-pod")
            && r.opt_str("trace") == Some(trace_hex.as_str())),
        "scheduler writes audited under its own actor + the pod's trace"
    );

    // -- labelled metric families in the Prometheus exposition -----------
    let prom = rpc.call("obs.Metrics/Prom", Value::Null).expect("Prom scrape");
    let text = prom.opt_str("text").expect("text body");
    assert!(
        text.contains("kube_api_create{gvk=\"pods\"}"),
        "API verb counters carry a gvk label"
    );
    assert!(
        text.contains("kube_events_emitted{reason=\"Scheduled\"}"),
        "event emission counters carry a reason label"
    );
    assert!(text.contains("# TYPE kube_api_audit_records counter"));

    tb.stop();
}
