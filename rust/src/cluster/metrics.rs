//! Process-wide metrics registry: counters, gauges, latency histograms —
//! organized as **labelled families** (PR 8).
//!
//! Every daemon records into a shared [`Metrics`] handle; the CLI's
//! `hpcorc metrics` and the bench harness read snapshots. Lock granularity
//! is per-metric-map; hot-path increments are atomics.
//!
//! A *family* is a metric name (`kube.api.create`); a *series* is one
//! (family, label set) pair. Series are stored under one canonical key
//! per label set ([`canonical_key`]: `family{k="v",...}` with pairs
//! sorted by key), so registry iteration — and therefore every snapshot
//! and the Prometheus exposition built on it — is deterministic.
//! [`Metrics::counter_value`] sums a whole family across its label sets,
//! which keeps pre-PR-8 call sites (`counter_value("kube.api.list")`)
//! correct after their write paths gained labels.

use crate::util::Hist;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Canonical registry key for one series: the bare family name when the
/// label set is empty, otherwise `family{k="v",...}` with pairs sorted
/// by key and values escaped Prometheus-style (`\\` and `\"`). One label
/// set has exactly one rendering, so it doubles as the exposition form.
pub fn canonical_key(family: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return family.to_string();
    }
    let mut pairs: Vec<(&str, &str)> = labels.to_vec();
    pairs.sort();
    let mut out = String::with_capacity(family.len() + 16 * pairs.len());
    out.push_str(family);
    out.push('{');
    for (i, (k, v)) in pairs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => out.push_str("\\\\"),
                '"' => out.push_str("\\\""),
                _ => out.push(c),
            }
        }
        out.push('"');
    }
    out.push('}');
    out
}

/// Split a canonical key into `(family, label-pair rendering)` —
/// `None` labels for a bare series.
pub fn split_key(key: &str) -> (&str, Option<&str>) {
    match key.split_once('{') {
        Some((family, rest)) => (family, Some(rest.strip_suffix('}').unwrap_or(rest))),
        None => (key, None),
    }
}

#[derive(Default)]
struct Inner {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    hists: Mutex<BTreeMap<String, Arc<Mutex<Hist>>>>,
}

/// Cloneable metrics registry handle.
#[derive(Clone, Default)]
pub struct Metrics {
    inner: Arc<Inner>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create a counter; returns a cheap handle for hot paths.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut m = self.inner.counters.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicU64::new(0))).clone()
    }

    /// Get-or-create one labelled series of a counter family.
    pub fn counter_with(&self, family: &str, labels: &[(&str, &str)]) -> Arc<AtomicU64> {
        self.counter(&canonical_key(family, labels))
    }

    pub fn inc(&self, name: &str) {
        self.counter(name).fetch_add(1, Ordering::Relaxed);
    }

    pub fn inc_with(&self, family: &str, labels: &[(&str, &str)]) {
        self.counter_with(family, labels).fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, name: &str, v: u64) {
        self.counter(name).fetch_add(v, Ordering::Relaxed);
    }

    pub fn add_with(&self, family: &str, labels: &[(&str, &str)], v: u64) {
        self.counter_with(family, labels).fetch_add(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, name: &str) -> Arc<AtomicI64> {
        let mut m = self.inner.gauges.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(AtomicI64::new(0))).clone()
    }

    pub fn gauge_with(&self, family: &str, labels: &[(&str, &str)]) -> Arc<AtomicI64> {
        self.gauge(&canonical_key(family, labels))
    }

    pub fn set_gauge(&self, name: &str, v: i64) {
        self.gauge(name).store(v, Ordering::Relaxed);
    }

    pub fn set_gauge_with(&self, family: &str, labels: &[(&str, &str)], v: i64) {
        self.gauge_with(family, labels).store(v, Ordering::Relaxed);
    }

    pub fn hist(&self, name: &str) -> Arc<Mutex<Hist>> {
        let mut m = self.inner.hists.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(|| Arc::new(Mutex::new(Hist::new()))).clone()
    }

    pub fn hist_with(&self, family: &str, labels: &[(&str, &str)]) -> Arc<Mutex<Hist>> {
        self.hist(&canonical_key(family, labels))
    }

    /// Record a duration in nanoseconds into a histogram.
    pub fn observe(&self, name: &str, nanos: u64) {
        self.hist(name).lock().unwrap().record(nanos);
    }

    /// Record into one labelled series of a histogram family.
    pub fn observe_with(&self, family: &str, labels: &[(&str, &str)], nanos: u64) {
        self.hist_with(family, labels).lock().unwrap().record(nanos);
    }

    /// Time a closure into a histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let r = f();
        self.observe(name, t0.elapsed().as_nanos() as u64);
        r
    }

    /// Snapshot all metrics as sorted (name, rendering) lines.
    pub fn snapshot(&self) -> Vec<(String, String)> {
        let mut out = Vec::new();
        for (k, v) in self.inner.counters.lock().unwrap().iter() {
            out.push((k.clone(), v.load(Ordering::Relaxed).to_string()));
        }
        for (k, v) in self.inner.gauges.lock().unwrap().iter() {
            out.push((k.clone(), v.load(Ordering::Relaxed).to_string()));
        }
        for (k, h) in self.inner.hists.lock().unwrap().iter() {
            out.push((k.clone(), h.lock().unwrap().summary(1e6, "ms")));
        }
        out.sort();
        out
    }

    /// Typed counter snapshot, sorted by name.
    pub fn counters_snapshot(&self) -> Vec<(String, u64)> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Typed gauge snapshot, sorted by name.
    pub fn gauges_snapshot(&self) -> Vec<(String, i64)> {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect()
    }

    /// Histogram snapshot (cloned), sorted by name — what the Prometheus
    /// renderer in `obs::prom` walks for cumulative buckets.
    pub fn hists_snapshot(&self) -> Vec<(String, Hist)> {
        self.inner
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), h.lock().unwrap().clone()))
            .collect()
    }

    /// Read a counter family's total across all its label sets (0 if
    /// absent) — test/bench helper. Pre-label call sites keep working:
    /// `counter_value("kube.api.list")` sums `kube.api.list{gvk="..."}`.
    pub fn counter_value(&self, family: &str) -> u64 {
        self.inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .filter(|(k, _)| split_key(k).0 == family)
            .map(|(_, c)| c.load(Ordering::Relaxed))
            .sum()
    }

    /// Read one exact labelled series of a counter family (0 if absent).
    pub fn counter_value_with(&self, family: &str, labels: &[(&str, &str)]) -> u64 {
        self.inner
            .counters
            .lock()
            .unwrap()
            .get(&canonical_key(family, labels))
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("jobs.submitted");
        m.add("jobs.submitted", 4);
        assert_eq!(m.counter_value("jobs.submitted"), 5);
        assert_eq!(m.counter_value("missing"), 0);
    }

    #[test]
    fn gauges_set() {
        let m = Metrics::new();
        m.set_gauge("queue.depth", 7);
        m.set_gauge("queue.depth", 3);
        assert_eq!(m.gauge("queue.depth").load(Ordering::Relaxed), 3);
    }

    #[test]
    fn hist_observe_and_time() {
        let m = Metrics::new();
        m.observe("lat", 1_000_000);
        let out = m.time("lat", || 42);
        assert_eq!(out, 42);
        assert_eq!(m.hist("lat").lock().unwrap().count(), 2);
    }

    #[test]
    fn snapshot_sorted() {
        let m = Metrics::new();
        m.inc("b.count");
        m.inc("a.count");
        m.observe("c.lat", 5);
        let snap = m.snapshot();
        let names: Vec<&str> = snap.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, ["a.count", "b.count", "c.lat"]);
    }

    #[test]
    fn handles_shared_across_clones() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.inc("x");
        assert_eq!(m.counter_value("x"), 1);
    }

    #[test]
    fn canonical_key_sorts_and_escapes() {
        assert_eq!(canonical_key("f", &[]), "f");
        assert_eq!(
            canonical_key("f", &[("z", "2"), ("a", "1")]),
            r#"f{a="1",z="2"}"#,
            "label pairs sort by key"
        );
        assert_eq!(
            canonical_key("f", &[("k", r#"a"b\c"#)]),
            r#"f{k="a\"b\\c"}"#,
            "values escape quotes and backslashes"
        );
        assert_eq!(split_key("f"), ("f", None));
        assert_eq!(split_key(r#"f{a="1"}"#), ("f", Some(r#"a="1""#)));
    }

    #[test]
    fn labelled_families_sum_in_counter_value() {
        let m = Metrics::new();
        m.inc_with("kube.api.create", &[("gvk", "pods")]);
        m.add_with("kube.api.create", &[("gvk", "nodes")], 2);
        m.inc("kube.api.create"); // bare series of the same family
        assert_eq!(m.counter_value("kube.api.create"), 4, "family total sums label sets");
        assert_eq!(m.counter_value_with("kube.api.create", &[("gvk", "pods")]), 1);
        assert_eq!(m.counter_value_with("kube.api.create", &[("gvk", "ghost")]), 0);
        // A label set is one series regardless of pair order at the call site.
        m.inc_with("f", &[("a", "1"), ("b", "2")]);
        m.inc_with("f", &[("b", "2"), ("a", "1")]);
        assert_eq!(m.counter_value_with("f", &[("a", "1"), ("b", "2")]), 2);
        // Family prefix must not leak into counter_value sums.
        m.inc("kube.api.creates");
        assert_eq!(m.counter_value("kube.api.create"), 4);
    }

    #[test]
    fn labelled_gauges_and_hists() {
        let m = Metrics::new();
        m.set_gauge_with("pool.size", &[("pool", "a")], 3);
        m.set_gauge_with("pool.size", &[("pool", "b")], 5);
        assert_eq!(m.gauge_with("pool.size", &[("pool", "a")]).load(Ordering::Relaxed), 3);
        m.observe_with("rpc_ns", &[("method", "kube.Api/Create")], 100);
        m.observe_with("rpc_ns", &[("method", "kube.Api/Create")], 200);
        assert_eq!(m.hist_with("rpc_ns", &[("method", "kube.Api/Create")]).lock().unwrap().count(), 2);
    }
}
