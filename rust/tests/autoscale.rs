//! Autoscale-layer integration: the full elastic loop, end to end through
//! the real metrics pipeline, HPA, cluster autoscaler, Kubernetes
//! scheduler, kueue admission, and the operator's red-box submission path
//! (a recording bridge stands in for the WLM, so "bursted onto the HPC
//! partition" is a hard assertion on what crossed red-box).
//!
//! The acceptance scenario, stepped deterministically:
//! 1. a Deployment under synthetic load scales up via HPA;
//! 2. the scale-up exhausts the static partition, so the cluster
//!    autoscaler provisions live kubelet-backed pool nodes up to its cap;
//! 3. with the K8s partition capped, a burst-labelled pod flips onto the
//!    virtual WLM node and its wrapped job is submitted over red-box;
//! 4. on load drop the HPA shrinks the Deployment and the autoscaler
//!    drains + removes an empty pool node — while the pool node hosting a
//!    gang-admitted kueue workload survives untouched.

use hpcorc::autoscale::{
    CaConfig, ClusterAutoscaler, HpaController, HpaView, NodeProvisioner, BURST_LABEL,
    CPU_USAGE_ANNOTATION,
};
use hpcorc::cluster::{Metrics, Resources, SharedFs};
use hpcorc::kube::{
    ApiServer, Controller, DeploymentController, KubeScheduler, Kubelet, NodeView, PodView,
    SharedInformerFactory, KIND_DEPLOYMENT, KIND_NODE, KIND_POD, KIND_TORQUEJOB,
};
use hpcorc::kueue::{
    is_admitted, AdmissionCore, ClusterQueueView, LocalQueueView, QueueResources,
};
use hpcorc::operator::{
    register_virtual_nodes, OperatorConfig, WlmBridge, WlmJobOperator, WlmStatus,
};
use hpcorc::singularity::{
    ImageRegistry, Payload, Runtime, RuntimeKind, SifImage, SingularityCri,
};
use hpcorc::util::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Records submissions/cancellations; job status is test-controlled.
struct RecordingBridge {
    submits: Mutex<Vec<String>>,
    status: Mutex<WlmStatus>,
    next: AtomicU64,
}

impl Default for RecordingBridge {
    fn default() -> Self {
        RecordingBridge {
            submits: Mutex::new(Vec::new()),
            status: Mutex::new(WlmStatus::Queued),
            next: AtomicU64::new(1),
        }
    }
}

impl RecordingBridge {
    fn submits(&self) -> Vec<String> {
        self.submits.lock().unwrap().clone()
    }
}

impl WlmBridge for RecordingBridge {
    fn submit(&self, script: &str, _user: &str) -> Result<String> {
        self.submits.lock().unwrap().push(script.to_string());
        Ok(format!("{}.rec-head", self.next.fetch_add(1, Ordering::SeqCst)))
    }
    fn status(&self, _job_id: &str) -> Result<WlmStatus> {
        Ok(self.status.lock().unwrap().clone())
    }
    fn cancel(&self, _job_id: &str) -> Result<()> {
        Ok(())
    }
    fn read_file(&self, _path: &str) -> Result<String> {
        Ok(String::new())
    }
    fn write_file(&self, _path: &str, _content: &str) -> Result<()> {
        Ok(())
    }
    fn queues(&self) -> Result<Vec<String>> {
        Ok(vec!["batch".into()])
    }
}

/// Provisioner backed by real kubelets the test steps by hand.
struct SteppedProvisioner {
    informers: SharedInformerFactory,
    runtime: Runtime,
    fs: SharedFs,
    capacity: Resources,
    kubelets: Mutex<Vec<Kubelet<Arc<SingularityCri>>>>,
    deprovisioned: Mutex<Vec<String>>,
}

impl NodeProvisioner for SteppedProvisioner {
    fn provision(&self, name: &str, labels: &[(&str, &str)]) -> Result<()> {
        let kubelet = Kubelet::register(
            &self.informers,
            name,
            self.capacity,
            labels,
            SingularityCri::new(self.runtime.clone()),
            self.fs.clone(),
            1.0,
            Metrics::new(),
        )?;
        self.kubelets.lock().unwrap().push(kubelet);
        Ok(())
    }
    fn deprovision(&self, name: &str) -> Result<()> {
        self.kubelets.lock().unwrap().retain(|k| k.node_name() != name);
        self.deprovisioned.lock().unwrap().push(name.to_string());
        Ok(())
    }
}

struct Env {
    api: ApiServer,
    deploy_ctrl: DeploymentController,
    sched: KubeScheduler,
    hpa: HpaController,
    ca: ClusterAutoscaler,
    core: AdmissionCore,
    operator: Arc<WlmJobOperator>,
    bridge: Arc<RecordingBridge>,
    provisioner: Arc<SteppedProvisioner>,
    static_kubelet: Kubelet<Arc<SingularityCri>>,
}

impl Env {
    /// One step of every control loop, in a scheduler-like order.
    fn step(&self) {
        let _ = self.deploy_ctrl.reconcile(&self.api, "web");
        let _ = self.core.cycle(&self.api);
        self.sched.run_cycle();
        self.static_kubelet.sync_once();
        for k in self.provisioner.kubelets.lock().unwrap().iter() {
            k.sync_once();
        }
        let _ = self.hpa.reconcile(&self.api, "h");
        let _ = self.ca.run_cycle();
        for job in self.api.list(KIND_TORQUEJOB, &[]) {
            let _ = self.operator.reconcile(&self.api, &job.meta.name);
        }
    }

    fn settle<F: Fn(&Env) -> bool>(&self, what: &str, pred: F) {
        let deadline = Instant::now() + Duration::from_secs(20);
        while !pred(self) {
            assert!(Instant::now() < deadline, "never converged: {what}");
            self.step();
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn replicas(&self) -> u32 {
        self.api
            .get(KIND_DEPLOYMENT, "web")
            .unwrap()
            .spec
            .opt_int("replicas")
            .unwrap_or(0) as u32
    }

    fn running_web_pods(&self) -> usize {
        self.api
            .list(KIND_POD, &[("deployment".to_string(), "web".to_string())])
            .iter()
            .filter(|p| p.status.opt_str("phase") == Some("Running"))
            .count()
    }

    fn pool_nodes(&self) -> Vec<String> {
        self.api
            .list(KIND_NODE, &[])
            .iter()
            .filter(|n| n.meta.label(hpcorc::autoscale::POOL_LABEL).is_some())
            .map(|n| n.meta.name.clone())
            .collect()
    }
}

fn env() -> Env {
    let api = ApiServer::new(Metrics::new());
    let images = ImageRegistry::with_defaults();
    // Service payload that outlives the test (kubelets run at 1.0 scale).
    images.push(SifImage::new("svc.sif", Payload::Sleep { millis: 600_000 }));
    let runtime = Runtime::new(RuntimeKind::Singularity, images, Metrics::new());
    let fs = SharedFs::new();
    let bridge = Arc::new(RecordingBridge::default());
    register_virtual_nodes(&api, bridge.as_ref(), "torque").unwrap();
    let informers = SharedInformerFactory::new(api.client(), Metrics::new());
    let static_kubelet = Kubelet::register(
        &informers,
        "static-0",
        Resources::cores(2, 64 << 30),
        &[],
        SingularityCri::new(runtime.clone()),
        fs.clone(),
        1.0,
        Metrics::new(),
    )
    .unwrap();
    let provisioner = Arc::new(SteppedProvisioner {
        informers: informers.clone(),
        runtime,
        fs,
        capacity: Resources::cores(2, 64 << 30),
        kubelets: Mutex::new(Vec::new()),
        deprovisioned: Mutex::new(Vec::new()),
    });
    let ca = ClusterAutoscaler::new(
        &informers,
        provisioner.clone(),
        CaConfig {
            pool_prefix: "ka".into(),
            node_capacity: Resources::cores(2, 64 << 30),
            min_nodes: 0,
            max_nodes: 2,
            scale_down_idle: Duration::from_millis(30),
            burst_wlm: Some("torque".into()),
            burst_walltime: Duration::from_secs(600),
        },
        Metrics::new(),
    );
    let wlm: Arc<dyn WlmBridge> = bridge.clone();
    Env {
        deploy_ctrl: DeploymentController::new(&informers),
        sched: KubeScheduler::new(&informers, Metrics::new()),
        hpa: HpaController::new(&informers, Duration::from_millis(1), Metrics::new()),
        ca,
        core: AdmissionCore::new(&informers, Metrics::new()),
        operator: WlmJobOperator::new(OperatorConfig::torque(), wlm, Metrics::new()),
        bridge,
        provisioner,
        static_kubelet,
        api,
    }
}

#[test]
fn full_elastic_loop_scale_up_burst_and_safe_scale_down() {
    let e = env();

    // --- 1. Deployment under synthetic load + HPA -------------------
    // Each replica requests 900m and reports 900m of usage (100%
    // utilization vs the 50% target): the HPA doubles until maxReplicas.
    let mut deploy =
        DeploymentController::build("web", 1, "svc.sif", Resources::new(900, 64 << 20, 0));
    deploy
        .spec
        .get_mut("template")
        .unwrap()
        .insert("env", hpcorc::encoding::Value::map().with("CPU_LOAD_MILLI", "900"));
    e.api.create(deploy).unwrap();
    e.api
        .create(HpaView::build("h", "web", 1, 6, 50, Duration::ZERO))
        .unwrap();

    // --- 2. HPA exhausts the static node; the CA grows the pool -----
    // 6 × 900m needs 5400m; static-0 holds 2000m, so both pool nodes
    // (2000m each) must come up for all six replicas to run.
    e.settle("hpa scale-up to max + pool grown + all running", |e| {
        e.replicas() == 6 && e.pool_nodes().len() == 2 && e.running_web_pods() == 6
    });
    let hpa = HpaView::from_object(&e.api.get(hpcorc::autoscale::KIND_HPA, "h").unwrap())
        .unwrap();
    assert_eq!(hpa.desired_replicas, Some(6));
    assert!(hpa.current_utilization_pct.unwrap_or(0) >= 90, "{hpa:?}");

    // A gang-admitted kueue workload lands on a pool node and stays
    // there for the rest of the test.
    e.api
        .create(ClusterQueueView::build("cq", QueueResources::nodes(1)))
        .unwrap();
    e.api.create(LocalQueueView::build("team", "cq")).unwrap();
    let mut gang = PodView::build("gang", "svc.sif", Resources::new(100, 1 << 20, 0), &[]);
    hpcorc::kueue::queue_workload(&mut gang, "team");
    gang.spec.insert(
        "nodeSelector",
        hpcorc::encoding::Value::map().with(hpcorc::autoscale::POOL_LABEL, "ka"),
    );
    e.api.create(gang).unwrap();
    e.settle("gang admitted, bound to a pool node, running", |e| {
        let g = e.api.get(KIND_POD, "gang").unwrap();
        is_admitted(&g)
            && g.spec.opt_str("nodeName").map(|n| n.starts_with("ka-")).unwrap_or(false)
            && g.status.opt_str("phase") == Some("Running")
    });
    let gang_node =
        e.api.get(KIND_POD, "gang").unwrap().spec.opt_str("nodeName").unwrap().to_string();

    // --- 3. Partition capped: the labelled pod bursts over red-box --
    let mut hpc = PodView::build("hpc", "work.sif", Resources::new(1000, 1 << 20, 0), &[]);
    hpc.meta.set_label(BURST_LABEL, "true");
    e.api.create(hpc).unwrap();
    e.settle("burst job submitted over red-box", |e| !e.bridge.submits().is_empty());
    let submits = e.bridge.submits();
    assert_eq!(submits.len(), 1);
    assert!(submits[0].contains("singularity run work.sif"), "{}", submits[0]);
    let pod = e.api.get(KIND_POD, "hpc").unwrap();
    assert_eq!(pod.spec.opt_str("nodeName"), Some("vnode-torque-batch"));
    assert_eq!(pod.status.opt_str("burstJob"), Some("burst-hpc"));
    // The WLM runs and finishes the job; the pod mirrors it.
    *e.bridge.status.lock().unwrap() = WlmStatus::Running;
    e.settle("bursted pod mirrors Running", |e| {
        e.api.get(KIND_POD, "hpc").unwrap().status.opt_str("phase") == Some("Running")
    });
    *e.bridge.status.lock().unwrap() = WlmStatus::Completed;
    e.settle("bursted pod mirrors completion", |e| {
        e.api.get(KIND_POD, "hpc").unwrap().status.opt_str("phase") == Some("Succeeded")
    });
    assert!(e.pool_nodes().len() <= 2, "burst must not grow the pool past its cap");

    // --- 4. Load drop: HPA shrinks, CA drains — but never the gang --
    for p in e.api.list(KIND_POD, &[("deployment".to_string(), "web".to_string())]) {
        e.api
            .update_status(KIND_POD, &p.meta.name, |o| {
                o.meta
                    .annotations
                    .push((CPU_USAGE_ANNOTATION.to_string(), "90".to_string()));
            })
            .unwrap();
    }
    e.settle("hpa scales the deployment back down", |e| e.replicas() == 1);
    e.settle("empty pool node drained and removed", |e| {
        !e.provisioner.deprovisioned.lock().unwrap().is_empty()
    });
    let removed = e.provisioner.deprovisioned.lock().unwrap().clone();
    assert!(!removed.contains(&gang_node), "the gang's node must never drain");
    for name in &removed {
        assert!(e.api.get(KIND_NODE, name).is_err(), "drained node object deleted");
    }
    // The gang-admitted workload survived the whole contraction.
    let gang = e.api.get(KIND_POD, "gang").unwrap();
    assert!(is_admitted(&gang), "gang still admitted");
    assert_eq!(gang.status.opt_str("phase"), Some("Running"), "gang never evicted");
    assert_eq!(gang.spec.opt_str("nodeName"), Some(gang_node.as_str()));
    let node = NodeView::from_object(&e.api.get(KIND_NODE, &gang_node).unwrap()).unwrap();
    assert!(!node.unschedulable, "gang's node was never cordoned");
}
