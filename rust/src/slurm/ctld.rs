//! slurmctld: the Slurm controller — partitions, job table, backfill loop.
//!
//! The baseline WLM behind WLM-Operator (paper §II). Same architecture as
//! [`crate::pbs::PbsServer`] with Slurm semantics: partitions instead of
//! queues, Slurm job states (PD/R/CD/CA/F/TO), sbatch/squeue/scancel/sacct/
//! scontrol verbs. Execution reuses the generic node daemon
//! ([`crate::pbs::Mom`]) with the `SLURM_*` environment flavor.

use super::script::SlurmScript;
use crate::cluster::{Metrics, NodeSpec, SharedFs};
use crate::pbs::mom::{JobDone, LaunchSpec, Mom, WlmFlavor};
use crate::rt::{self, Shutdown, Timers};
use crate::sched::{NodeState, PendingJob, RunningJob, SchedPolicy};
use crate::singularity::Runtime;
use crate::util::{Error, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Slurm job states (squeue codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlurmJobState {
    Pending,
    Running,
    Completed,
    Cancelled,
    Failed,
    Timeout,
}

impl SlurmJobState {
    pub fn code(&self) -> &'static str {
        match self {
            SlurmJobState::Pending => "PD",
            SlurmJobState::Running => "R",
            SlurmJobState::Completed => "CD",
            SlurmJobState::Cancelled => "CA",
            SlurmJobState::Failed => "F",
            SlurmJobState::Timeout => "TO",
        }
    }

    pub fn terminal(&self) -> bool {
        !matches!(self, SlurmJobState::Pending | SlurmJobState::Running)
    }
}

/// A Slurm partition (queue analog).
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub name: String,
    pub nodes: Vec<String>,
    pub max_time: Option<Duration>,
    pub priority: i64,
    pub is_default: bool,
}

impl Partition {
    pub fn new(name: impl Into<String>, nodes: &[&str]) -> Self {
        Partition {
            name: name.into(),
            nodes: nodes.iter().map(|s| s.to_string()).collect(),
            max_time: None,
            priority: 0,
            is_default: false,
        }
    }

    pub fn default_partition(mut self) -> Self {
        self.is_default = true;
        self
    }

    pub fn with_max_time(mut self, d: Duration) -> Self {
        self.max_time = Some(d);
        self
    }
}

/// One job's record.
#[derive(Debug, Clone)]
pub struct SlurmJob {
    pub id: u64,
    pub script: SlurmScript,
    pub partition: String,
    pub user: String,
    pub state: SlurmJobState,
    pub submit_s: f64,
    pub start_s: Option<f64>,
    pub end_s: Option<f64>,
    pub placement: Vec<String>,
    pub exit_code: Option<i32>,
}

impl SlurmJob {
    pub fn name(&self) -> &str {
        self.script.name.as_deref().unwrap_or("sbatch")
    }
}

struct NodeAlloc {
    spec: NodeSpec,
    used_cores: u32,
    used_mem: u64,
}

struct CtldState {
    jobs: BTreeMap<u64, SlurmJob>,
    nodes: Vec<NodeAlloc>,
}

pub struct SlurmConfig {
    pub cluster_name: String,
    pub partitions: Vec<Partition>,
    pub sched_period: Duration,
    pub time_scale: f64,
}

impl Default for SlurmConfig {
    fn default() -> Self {
        SlurmConfig {
            cluster_name: "slurm".into(),
            partitions: vec![Partition::new("normal", &[]).default_partition()],
            sched_period: Duration::from_millis(5),
            time_scale: 1.0,
        }
    }
}

#[derive(Clone)]
pub struct Slurmctld {
    inner: Arc<Inner>,
}

struct Inner {
    name: String,
    partitions: Vec<Partition>,
    policy: Box<dyn SchedPolicy>,
    state: Mutex<CtldState>,
    moms: Mutex<HashMap<String, Mom>>,
    metrics: Metrics,
    time_scale: f64,
    epoch: Instant,
    seq: AtomicU64,
    fs: SharedFs,
}

impl Slurmctld {
    pub fn start(
        config: SlurmConfig,
        compute_nodes: Vec<NodeSpec>,
        runtime: Runtime,
        fs: SharedFs,
        policy: Box<dyn SchedPolicy>,
        timers: Timers,
        metrics: Metrics,
        shutdown: Shutdown,
    ) -> Result<Slurmctld> {
        if config.partitions.is_empty() {
            return Err(Error::config("slurmctld needs at least one partition"));
        }
        let (done_tx, done_rx) = channel::<JobDone>();
        let mut moms = HashMap::new();
        for spec in &compute_nodes {
            let mom = Mom::new(
                spec.clone(),
                fs.clone(),
                runtime.clone(),
                timers.clone(),
                config.time_scale,
                done_tx.clone(),
                metrics.clone(),
                shutdown.clone(),
            )
            .with_flavor(WlmFlavor::Slurm);
            moms.insert(spec.name.clone(), mom);
        }
        let inner = Arc::new(Inner {
            name: config.cluster_name,
            partitions: config.partitions,
            policy,
            state: Mutex::new(CtldState {
                jobs: BTreeMap::new(),
                nodes: compute_nodes
                    .into_iter()
                    .map(|spec| NodeAlloc { spec, used_cores: 0, used_mem: 0 })
                    .collect(),
            }),
            moms: Mutex::new(moms),
            metrics,
            time_scale: config.time_scale.max(1e-9),
            epoch: Instant::now(),
            seq: AtomicU64::new(1),
            fs,
        });
        let ctld = Slurmctld { inner };

        let c2 = ctld.clone();
        let sd2 = shutdown.clone();
        rt::spawn_named("slurm-events", move || loop {
            match done_rx.recv_timeout(Duration::from_millis(20)) {
                Ok(done) => c2.on_job_done(done),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if sd2.is_triggered() {
                        return;
                    }
                }
                Err(_) => return,
            }
        });
        let c3 = ctld.clone();
        rt::pool::spawn_ticker("slurm-sched", config.sched_period, shutdown, move || {
            c3.run_sched_cycle();
        });
        Ok(ctld)
    }

    pub fn cluster_name(&self) -> &str {
        &self.inner.name
    }

    pub fn fs(&self) -> &SharedFs {
        &self.inner.fs
    }

    pub fn partitions(&self) -> &[Partition] {
        &self.inner.partitions
    }

    pub fn now_s(&self) -> f64 {
        self.inner.epoch.elapsed().as_secs_f64() / self.inner.time_scale
    }

    fn resolve_partition(&self, requested: Option<&str>) -> Result<&Partition> {
        match requested {
            Some(name) => self
                .inner
                .partitions
                .iter()
                .find(|p| p.name == name)
                .ok_or_else(|| Error::wlm(format!("invalid partition `{name}`"))),
            None => self
                .inner
                .partitions
                .iter()
                .find(|p| p.is_default)
                .or_else(|| self.inner.partitions.first())
                .ok_or_else(|| Error::wlm("no default partition")),
        }
    }

    // ------------------------------------------------------------- commands

    /// `sbatch`: submit. Returns the numeric job id.
    pub fn sbatch(&self, script_text: &str, user: &str) -> Result<u64> {
        let script = SlurmScript::parse(script_text)?;
        self.sbatch_parsed(script, user)
    }

    pub fn sbatch_parsed(&self, script: SlurmScript, user: &str) -> Result<u64> {
        let partition = self.resolve_partition(script.partition.as_deref())?.clone();
        if let Some(max) = partition.max_time {
            if script.time > max {
                return Err(Error::wlm(format!(
                    "time limit exceeds partition `{}` max",
                    partition.name
                )));
            }
        }
        {
            let state = self.inner.state.lock().unwrap();
            let feasible = state
                .nodes
                .iter()
                .filter(|n| {
                    let in_part =
                        partition.nodes.is_empty() || partition.nodes.contains(&n.spec.name);
                    let cores = (n.spec.capacity.cpu_milli / 1000) as u32;
                    in_part
                        && cores >= script.tasks_per_node
                        && n.spec.capacity.mem_bytes >= script.mem
                        && script.constraints.iter().all(|c| n.spec.has_feature(c))
                })
                .count()
                >= script.nodes as usize;
            if !feasible {
                return Err(Error::wlm(
                    "sbatch: requested node configuration is not available",
                ));
            }
        }
        let id = self.inner.seq.fetch_add(1, Ordering::Relaxed);
        let job = SlurmJob {
            id,
            script,
            partition: partition.name.clone(),
            user: user.to_string(),
            state: SlurmJobState::Pending,
            submit_s: self.now_s(),
            start_s: None,
            end_s: None,
            placement: Vec::new(),
            exit_code: None,
        };
        self.inner.state.lock().unwrap().jobs.insert(id, job);
        self.inner.metrics.inc("slurm.jobs_submitted");
        Ok(id)
    }

    /// `squeue`: non-terminal jobs.
    pub fn squeue(&self) -> Vec<SlurmJob> {
        self.inner
            .state
            .lock()
            .unwrap()
            .jobs
            .values()
            .filter(|j| !j.state.terminal())
            .cloned()
            .collect()
    }

    /// `sacct`: all jobs including terminal (accounting view).
    pub fn sacct(&self) -> Vec<SlurmJob> {
        self.inner.state.lock().unwrap().jobs.values().cloned().collect()
    }

    /// `scontrol show job`.
    pub fn scontrol_show(&self, id: u64) -> Result<SlurmJob> {
        self.inner
            .state
            .lock()
            .unwrap()
            .jobs
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::wlm(format!("Invalid job id specified: {id}")))
    }

    /// `scancel`.
    pub fn scancel(&self, id: u64) -> Result<()> {
        let mom_to_cancel = {
            let mut state = self.inner.state.lock().unwrap();
            let now = self.now_s();
            let job = state
                .jobs
                .get_mut(&id)
                .ok_or_else(|| Error::wlm(format!("Invalid job id specified: {id}")))?;
            match job.state {
                SlurmJobState::Pending => {
                    job.state = SlurmJobState::Cancelled;
                    job.end_s = Some(now);
                    None
                }
                SlurmJobState::Running => {
                    job.state = SlurmJobState::Cancelled; // CG→CA collapsed
                    job.placement.first().cloned()
                }
                _ => None,
            }
        };
        if let Some(node) = mom_to_cancel {
            if let Some(mom) = self.inner.moms.lock().unwrap().get(&node) {
                mom.cancel(id);
            }
        }
        Ok(())
    }

    pub fn wait_for(&self, id: u64, timeout: Duration) -> Result<SlurmJob> {
        let deadline = Instant::now() + timeout;
        loop {
            let job = self.scontrol_show(id)?;
            if job.state.terminal() {
                return Ok(job);
            }
            if Instant::now() >= deadline {
                return Err(Error::wlm(format!("timeout waiting for job {id}")));
            }
            std::thread::sleep(Duration::from_micros(500));
        }
    }

    /// `sinfo`-style node view: `(node, used_cores, total_cores)`.
    pub fn sinfo(&self) -> Vec<(String, u32, u32)> {
        self.inner
            .state
            .lock()
            .unwrap()
            .nodes
            .iter()
            .map(|n| {
                (n.spec.name.clone(), n.used_cores, (n.spec.capacity.cpu_milli / 1000) as u32)
            })
            .collect()
    }

    // ------------------------------------------------------------ scheduling

    pub fn run_sched_cycle(&self) {
        let now = self.now_s();
        let launches = {
            let mut state = self.inner.state.lock().unwrap();
            let mut launches: Vec<(String, LaunchSpec)> = Vec::new();
            let mut parts: Vec<&Partition> = self.inner.partitions.iter().collect();
            parts.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.name.cmp(&b.name)));
            for part in parts {
                let pending: Vec<PendingJob> = state
                    .jobs
                    .values()
                    .filter(|j| j.state == SlurmJobState::Pending && j.partition == part.name)
                    .map(|j| PendingJob {
                        id: j.id,
                        nodes: j.script.nodes,
                        ppn: j.script.tasks_per_node,
                        mem: j.script.mem,
                        walltime: j.script.time,
                        priority: j.script.priority + part.priority,
                        submit_s: j.submit_s,
                        queue: Some(j.partition.clone()),
                    })
                    .collect();
                if pending.is_empty() {
                    continue;
                }
                // Snapshot partition nodes.
                let mut node_states = Vec::new();
                let mut names = Vec::new();
                for alloc in &state.nodes {
                    let in_part =
                        part.nodes.is_empty() || part.nodes.contains(&alloc.spec.name);
                    if in_part {
                        let total = (alloc.spec.capacity.cpu_milli / 1000) as u32;
                        node_states.push(NodeState {
                            id: names.len(),
                            total_cores: total,
                            free_cores: total.saturating_sub(alloc.used_cores),
                            total_mem: alloc.spec.capacity.mem_bytes,
                            free_mem: alloc
                                .spec
                                .capacity
                                .mem_bytes
                                .saturating_sub(alloc.used_mem),
                        });
                        names.push(alloc.spec.name.clone());
                    }
                }
                if node_states.is_empty() {
                    continue;
                }
                let name_to_idx: HashMap<&str, usize> =
                    names.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
                let running: Vec<RunningJob> = state
                    .jobs
                    .values()
                    .filter(|j| j.state == SlurmJobState::Running)
                    .map(|j| RunningJob {
                        id: j.id,
                        placement: j
                            .placement
                            .iter()
                            .filter_map(|n| name_to_idx.get(n.as_str()))
                            .map(|&node| crate::sched::Placement {
                                node,
                                cores: j.script.tasks_per_node,
                                mem: j.script.mem,
                            })
                            .collect(),
                        expected_end_s: j.start_s.unwrap_or(0.0)
                            + j.script.time.as_secs_f64(),
                    })
                    .collect();
                for a in self.inner.policy.schedule(now, &pending, &node_states, &running) {
                    let chosen: Vec<String> =
                        a.placement.iter().map(|p| names[p.node].clone()).collect();
                    let job = state.jobs.get_mut(&a.job).expect("assigned job exists");
                    job.state = SlurmJobState::Running;
                    job.start_s = Some(now);
                    job.placement = chosen.clone();
                    let spec = LaunchSpec {
                        job_seq: job.id,
                        job_name: job.name().to_string(),
                        body: job.script.body.clone(),
                        env: job.script.env.clone(),
                        stdout_path: job.script.output.clone(),
                        stderr_path: job.script.error.clone(),
                        walltime: job.script.time,
                        seed: job.id,
                    };
                    let (ppn, mem) = (job.script.tasks_per_node, job.script.mem);
                    for name in &chosen {
                        if let Some(alloc) =
                            state.nodes.iter_mut().find(|n| &n.spec.name == name)
                        {
                            alloc.used_cores += ppn;
                            alloc.used_mem += mem;
                        }
                    }
                    launches.push((chosen[0].clone(), spec));
                }
            }
            launches
        };
        for (node, spec) in launches {
            if let Some(mom) = self.inner.moms.lock().unwrap().get(&node) {
                self.inner.metrics.inc("slurm.jobs_started");
                mom.launch(spec);
            }
        }
        self.inner.metrics.inc("slurm.sched_cycles");
    }

    fn on_job_done(&self, done: JobDone) {
        let mut state = self.inner.state.lock().unwrap();
        let now = self.now_s();
        let Some(job) = state.jobs.get_mut(&done.job_seq) else { return };
        if job.state.terminal() {
            // scancel already marked it; still need to free resources below.
        } else {
            job.state = if done.walltime_exceeded {
                SlurmJobState::Timeout
            } else if done.cancelled {
                SlurmJobState::Cancelled
            } else if done.exit_code == 0 {
                SlurmJobState::Completed
            } else {
                SlurmJobState::Failed
            };
        }
        job.end_s = Some(now);
        job.exit_code = Some(done.exit_code);
        let (ppn, mem) = (job.script.tasks_per_node, job.script.mem);
        let placement = std::mem::take(&mut job.placement);
        // keep placement for sacct display
        let placement_copy = placement.clone();
        for name in &placement {
            if let Some(alloc) = state.nodes.iter_mut().find(|n| &n.spec.name == name) {
                alloc.used_cores = alloc.used_cores.saturating_sub(ppn);
                alloc.used_mem = alloc.used_mem.saturating_sub(mem);
            }
        }
        if let Some(job) = state.jobs.get_mut(&done.job_seq) {
            job.placement = placement_copy;
        }
        self.inner.metrics.inc("slurm.jobs_completed");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeRole, Resources};
    use crate::sched::EasyBackfill;
    use crate::singularity::{ImageRegistry, RuntimeKind};

    fn boot(n: usize, cores: u32) -> (Slurmctld, Shutdown) {
        let sd = Shutdown::new();
        let (timers, _) = Timers::start(sd.clone());
        let fs = SharedFs::new();
        let runtime = Runtime::new(
            RuntimeKind::Singularity,
            ImageRegistry::with_defaults(),
            Metrics::new(),
        );
        let nodes: Vec<NodeSpec> = (0..n)
            .map(|i| {
                NodeSpec::new(
                    format!("node{i:02}"),
                    NodeRole::TorqueCompute,
                    Resources::cores(cores, 32 << 30),
                )
            })
            .collect();
        let mut cfg = SlurmConfig::default();
        cfg.time_scale = 0.001;
        cfg.sched_period = Duration::from_millis(2);
        let ctld = Slurmctld::start(
            cfg,
            nodes,
            runtime,
            fs,
            Box::new(EasyBackfill),
            timers,
            Metrics::new(),
            sd.clone(),
        )
        .unwrap();
        (ctld, sd)
    }

    #[test]
    fn sbatch_lifecycle_with_singularity() {
        let (ctld, sd) = boot(2, 8);
        let id = ctld
            .sbatch(
                "#!/bin/sh\n#SBATCH --nodes=1\n#SBATCH --time=00:30:00\n#SBATCH -o $HOME/low.out\nsingularity run lolcow_latest.sif\n",
                "user",
            )
            .unwrap();
        let job = ctld.wait_for(id, Duration::from_secs(10)).unwrap();
        assert_eq!(job.state, SlurmJobState::Completed);
        assert!(ctld.fs().read_string("$HOME/low.out").unwrap().contains("Moo"));
        sd.trigger();
    }

    #[test]
    fn slurm_env_exposed() {
        let (ctld, sd) = boot(1, 8);
        let id = ctld
            .sbatch("#SBATCH -J envtest\n#SBATCH -o $HOME/env.out\necho id=$SLURM_JOB_ID name=$SLURM_JOB_NAME\n", "u")
            .unwrap();
        ctld.wait_for(id, Duration::from_secs(10)).unwrap();
        assert_eq!(
            ctld.fs().read_string("$HOME/env.out").unwrap(),
            format!("id={id} name=envtest\n")
        );
        sd.trigger();
    }

    #[test]
    fn states_and_scancel() {
        let (ctld, sd) = boot(1, 4);
        let running = ctld.sbatch("#SBATCH --ntasks-per-node=4\nsleep 500\n", "u").unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(ctld.scontrol_show(running).unwrap().state, SlurmJobState::Running);
        let pending = ctld.sbatch("#SBATCH --ntasks-per-node=4\necho x\n", "u").unwrap();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(ctld.scontrol_show(pending).unwrap().state, SlurmJobState::Pending);
        assert_eq!(ctld.squeue().len(), 2);
        ctld.scancel(pending).unwrap();
        assert_eq!(ctld.scontrol_show(pending).unwrap().state, SlurmJobState::Cancelled);
        ctld.scancel(running).unwrap();
        let j = ctld.wait_for(running, Duration::from_secs(10)).unwrap();
        assert_eq!(j.state, SlurmJobState::Cancelled);
        // scancel marks terminal immediately (CG collapsed); the mom's
        // completion report frees resources shortly after.
        let deadline = Instant::now() + Duration::from_secs(5);
        while ctld.sinfo()[0].1 != 0 {
            assert!(Instant::now() < deadline, "resources never freed");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(ctld.scancel(999).is_err());
        sd.trigger();
    }

    #[test]
    fn failed_and_timeout_states() {
        let (ctld, sd) = boot(2, 4);
        let fail = ctld.sbatch("exit 2\n", "u").unwrap();
        assert_eq!(ctld.wait_for(fail, Duration::from_secs(10)).unwrap().state, SlurmJobState::Failed);
        // 5s limit (5ms scaled) vs 60s sleep (60ms scaled)
        let to = ctld.sbatch("#SBATCH -t 0:05\nsleep 60\n", "u").unwrap();
        assert_eq!(ctld.wait_for(to, Duration::from_secs(10)).unwrap().state, SlurmJobState::Timeout);
        sd.trigger();
    }

    #[test]
    fn partition_limits() {
        let (ctld, sd) = boot(2, 8);
        assert!(ctld.sbatch("#SBATCH -p nope\necho x\n", "u").is_err());
        assert!(ctld.sbatch("#SBATCH -N 3\necho x\n", "u").is_err(), "infeasible");
        sd.trigger();
    }

    #[test]
    fn sacct_keeps_history() {
        let (ctld, sd) = boot(2, 8);
        let a = ctld.sbatch("echo a\n", "alice").unwrap();
        ctld.wait_for(a, Duration::from_secs(10)).unwrap();
        assert!(ctld.squeue().is_empty());
        let acct = ctld.sacct();
        assert_eq!(acct.len(), 1);
        assert_eq!(acct[0].user, "alice");
        assert!(!acct[0].placement.is_empty(), "placement kept for sacct");
        sd.trigger();
    }
}
