//! Cluster Events (PR 8): the `events.k8s.io/v1`-shaped `Event` kind and
//! the write-coalescing [`EventRecorder`] components emit through.
//!
//! Events are plain API objects — they ride the same store / WAL / watch
//! machinery as Pods — so `kubectl get events` and `kubectl describe`
//! need no new transport. Shape (mirroring `events.k8s.io/v1`):
//!
//! - `spec.regarding.{kind,name}` — the object the event is about
//! - `spec.type` — `Normal` or `Warning`
//! - `spec.reason` — CamelCase machine token (`Scheduled`, `Killing`, ...)
//! - `spec.note` — human message
//! - `spec.reportingController` — the emitting component
//! - `status.{count,firstSeen,lastSeen}` — dedup bookkeeping (server
//!   seconds, like every AGE column)
//!
//! Each event also carries the regarding object's `hpcorc.io/trace`
//! annotation, so `kubectl describe` can interleave events with the
//! causal span timeline of the same trace.
//!
//! **Coalescing**: a second `(object, reason)` emission within the
//! recorder's window bumps `status.count` + `lastSeen` on the existing
//! event instead of minting a new object — the k8s events-spam defence.
//! **GC**: [`gc_expired`] reaps events whose `lastSeen` is older than a
//! TTL; the testbed runs it on a ticker.

use super::api::KubeObject;
use super::client::{ApiClient, ListOptions, ResourceView};
use crate::cluster::Metrics;
use crate::encoding::Value;
use crate::obs::TRACE_ANNOTATION;
use crate::util::{ApiError, Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

pub const KIND_EVENT: &str = "Event";

/// The apiVersion events are served under (k8s `events.k8s.io/v1`).
pub const EVENTS_API_VERSION: &str = "events.k8s.io/v1";

/// Routine lifecycle event (`spec.type`).
pub const EVENT_NORMAL: &str = "Normal";
/// Something went wrong (`spec.type`).
pub const EVENT_WARNING: &str = "Warning";

/// Default coalescing window: repeats of `(object, reason)` within this
/// many server-seconds fold into a count bump.
pub const DEFAULT_COALESCE_WINDOW_S: f64 = 300.0;

/// Typed view over an Event object.
#[derive(Debug, Clone, PartialEq)]
pub struct EventView {
    pub name: String,
    pub regarding_kind: String,
    pub regarding_name: String,
    /// `Normal` or `Warning`.
    pub etype: String,
    pub reason: String,
    pub note: String,
    pub reporting_controller: String,
    pub count: u64,
    pub first_seen_s: f64,
    pub last_seen_s: f64,
    /// The `hpcorc.io/trace` annotation (`<trace_id>-<span_id>` hex),
    /// copied from the regarding object at emission time.
    pub trace: Option<String>,
}

impl EventView {
    pub fn from_object(o: &KubeObject) -> Result<EventView> {
        if o.kind != KIND_EVENT {
            return Err(Error::parse(format!("expected Event, got {}", o.kind)));
        }
        let regarding = o.spec.req("regarding")?;
        Ok(EventView {
            name: o.meta.name.clone(),
            regarding_kind: regarding.req_str("kind")?.to_string(),
            regarding_name: regarding.req_str("name")?.to_string(),
            etype: o.spec.opt_str("type").unwrap_or(EVENT_NORMAL).to_string(),
            reason: o.spec.opt_str("reason").unwrap_or("").to_string(),
            note: o.spec.opt_str("note").unwrap_or("").to_string(),
            reporting_controller: o
                .spec
                .opt_str("reportingController")
                .unwrap_or("")
                .to_string(),
            count: o.status.opt_int("count").unwrap_or(1).max(1) as u64,
            first_seen_s: o.status.get("firstSeen").and_then(Value::as_f64).unwrap_or(0.0),
            last_seen_s: o.status.get("lastSeen").and_then(Value::as_f64).unwrap_or(0.0),
            trace: o.meta.annotation(TRACE_ANNOTATION).map(String::from),
        })
    }

    /// The `<trace_id>` half of the carried annotation.
    pub fn trace_id(&self) -> Option<&str> {
        self.trace.as_deref().map(|t| t.split('-').next().unwrap_or(t))
    }

    /// Build an Event object (count=1, firstSeen=lastSeen=`now_s`).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        name: &str,
        regarding_kind: &str,
        regarding_name: &str,
        etype: &str,
        reason: &str,
        note: &str,
        component: &str,
        now_s: f64,
    ) -> KubeObject {
        let spec = Value::map()
            .with(
                "regarding",
                Value::map().with("kind", regarding_kind).with("name", regarding_name),
            )
            .with("type", etype)
            .with("reason", reason)
            .with("note", note)
            .with("reportingController", component);
        let mut o = KubeObject::new(KIND_EVENT, name, spec);
        o.api_version = EVENTS_API_VERSION.into();
        o.status = Value::map()
            .with("count", 1u64)
            .with("firstSeen", now_s)
            .with("lastSeen", now_s);
        o
    }
}

impl ResourceView for EventView {
    fn kinds() -> &'static [&'static str] {
        &[KIND_EVENT]
    }
    fn from_object(obj: &KubeObject) -> Result<EventView> {
        EventView::from_object(obj)
    }
}

/// Per-component event emitter with write coalescing. Cheap to clone
/// (clones share the dedup map); every control loop owns one:
///
/// ```ignore
/// let rec = EventRecorder::new("kube-scheduler", metrics.clone());
/// rec.event(&api, &pod, EVENT_NORMAL, "Scheduled", "bound to w1")?;
/// ```
#[derive(Clone)]
pub struct EventRecorder {
    component: String,
    window_s: f64,
    metrics: Metrics,
    inner: Arc<RecorderInner>,
}

struct RecorderInner {
    /// (regarding kind, regarding name, reason) → (event object name,
    /// window start in server seconds).
    recent: Mutex<HashMap<(String, String, String), (String, f64)>>,
    seq: AtomicU64,
}

impl EventRecorder {
    pub fn new(component: &str, metrics: Metrics) -> EventRecorder {
        EventRecorder {
            component: component.to_string(),
            window_s: DEFAULT_COALESCE_WINDOW_S,
            metrics,
            inner: Arc::new(RecorderInner {
                recent: Mutex::new(HashMap::new()),
                seq: AtomicU64::new(0),
            }),
        }
    }

    /// Override the coalescing window (tests use tiny windows).
    pub fn with_window_s(mut self, window_s: f64) -> EventRecorder {
        self.window_s = window_s;
        self
    }

    pub fn component(&self) -> &str {
        &self.component
    }

    /// Emit an event about a live object; the object's `hpcorc.io/trace`
    /// annotation is carried onto the event.
    pub fn event(
        &self,
        api: &dyn ApiClient,
        regarding: &KubeObject,
        etype: &str,
        reason: &str,
        note: &str,
    ) -> Result<()> {
        self.event_ref(
            api,
            &regarding.kind,
            regarding.name(),
            regarding.meta.annotation(TRACE_ANNOTATION),
            etype,
            reason,
            note,
        )
    }

    /// Emit an event by reference — for objects already deleted (the
    /// kubelet's `Reaped` fires after the pod is gone) or not at hand.
    /// `trace` is the regarding object's `hpcorc.io/trace` annotation
    /// value, when known.
    #[allow(clippy::too_many_arguments)]
    pub fn event_ref(
        &self,
        api: &dyn ApiClient,
        regarding_kind: &str,
        regarding_name: &str,
        trace: Option<&str>,
        etype: &str,
        reason: &str,
        note: &str,
    ) -> Result<()> {
        let now = api.server_time_s()?;
        let key =
            (regarding_kind.to_string(), regarding_name.to_string(), reason.to_string());

        // Within the window? Bump the existing event instead of creating.
        let existing = {
            let recent = self.inner.recent.lock().unwrap();
            recent
                .get(&key)
                .filter(|(_, start)| now - start < self.window_s)
                .map(|(n, _)| n.clone())
        };
        if let Some(ev_name) = existing {
            match self.bump(api, &ev_name, note, now) {
                Ok(()) => {
                    self.metrics.inc_with("kube.events.coalesced", &[("reason", reason)]);
                    return Ok(());
                }
                // GC (or a user) deleted it under us: mint a fresh one.
                Err(e) if e.is_not_found() => {}
                Err(e) => return Err(e),
            }
        }

        let seq = self.inner.seq.fetch_add(1, Ordering::Relaxed);
        let ev_name = format!(
            "{}.{}.{}.{}",
            regarding_name.to_ascii_lowercase(),
            reason.to_ascii_lowercase(),
            self.component,
            seq
        );
        let mut ev = EventView::build(
            &ev_name,
            regarding_kind,
            regarding_name,
            etype,
            reason,
            note,
            &self.component,
            now,
        );
        if let Some(t) = trace {
            ev.meta.set_annotation(TRACE_ANNOTATION, t);
        }
        match api.create(ev) {
            Ok(_) => {}
            // Another clone of this recorder raced us to the same name.
            Err(Error::Api(ApiError::AlreadyExists { .. })) => {
                self.bump(api, &ev_name, note, now)?;
            }
            Err(e) => return Err(e),
        }
        self.metrics.inc_with("kube.events.emitted", &[("reason", reason)]);

        let mut recent = self.inner.recent.lock().unwrap();
        recent.insert(key, (ev_name, now));
        // Drop stale entries so long-lived recorders stay bounded.
        let window = self.window_s;
        recent.retain(|_, (_, start)| now - *start < window);
        Ok(())
    }

    fn bump(&self, api: &dyn ApiClient, ev_name: &str, note: &str, now: f64) -> Result<()> {
        let note = note.to_string();
        api.update_status(KIND_EVENT, ev_name, &move |o| {
            let count = o.status.opt_int("count").unwrap_or(1).max(1) as u64;
            o.status.insert("count", count + 1);
            o.status.insert("lastSeen", now);
            o.spec.insert("note", note.clone());
        })
        .map(|_| ())
    }
}

/// Delete events whose `lastSeen` is older than `ttl_s` server-seconds;
/// returns how many were reaped. The testbed ticks this periodically.
pub fn gc_expired(api: &dyn ApiClient, metrics: &Metrics, ttl_s: f64) -> Result<usize> {
    let now = api.server_time_s()?;
    let list = api.list(KIND_EVENT, &ListOptions::all())?;
    let mut reaped = 0;
    for o in &list.items {
        let last = match EventView::from_object(o) {
            Ok(v) => v.last_seen_s,
            Err(_) => continue,
        };
        if now - last > ttl_s {
            match api.delete(KIND_EVENT, o.name()) {
                Ok(_) => reaped += 1,
                // Raced another reaper: fine.
                Err(e) if e.is_not_found() => {}
                Err(e) => return Err(e),
            }
        }
    }
    if reaped > 0 {
        metrics.add("kube.events.gc", reaped as u64);
    }
    Ok(reaped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Resources;
    use crate::kube::api::PodView;
    use crate::kube::ApiServer;

    fn client() -> Arc<dyn ApiClient> {
        Arc::new(ApiServer::new(Metrics::new()))
    }

    #[test]
    fn event_view_roundtrip() {
        let o = EventView::build(
            "p1.scheduled.sched.0",
            "Pod",
            "p1",
            EVENT_NORMAL,
            "Scheduled",
            "bound to w1",
            "kube-scheduler",
            12.5,
        );
        assert_eq!(o.api_version, EVENTS_API_VERSION);
        let v = EventView::from_object(&o).unwrap();
        assert_eq!(v.regarding_kind, "Pod");
        assert_eq!(v.regarding_name, "p1");
        assert_eq!(v.etype, EVENT_NORMAL);
        assert_eq!(v.reason, "Scheduled");
        assert_eq!(v.note, "bound to w1");
        assert_eq!(v.reporting_controller, "kube-scheduler");
        assert_eq!(v.count, 1);
        assert_eq!(v.first_seen_s, 12.5);
        assert_eq!(v.last_seen_s, 12.5);
        assert_eq!(v.trace, None);
        assert!(EventView::from_object(&PodView::build(
            "p",
            "i.sif",
            Resources::ZERO,
            &[]
        ))
        .is_err());
    }

    #[test]
    fn recorder_emits_and_coalesces() {
        let api = client();
        let metrics = Metrics::new();
        let rec = EventRecorder::new("tester", metrics.clone());
        let pod = api.create(PodView::build("p1", "i.sif", Resources::ZERO, &[])).unwrap();

        rec.event(&api, &pod, EVENT_WARNING, "FailedScheduling", "no fit").unwrap();
        rec.event(&api, &pod, EVENT_WARNING, "FailedScheduling", "still no fit").unwrap();
        rec.event(&api, &pod, EVENT_NORMAL, "Scheduled", "bound").unwrap();

        let events = api.list(KIND_EVENT, &ListOptions::all()).unwrap().items;
        assert_eq!(events.len(), 2, "repeat (object, reason) coalesced");
        let failed = events
            .iter()
            .map(|o| EventView::from_object(o).unwrap())
            .find(|v| v.reason == "FailedScheduling")
            .unwrap();
        assert_eq!(failed.count, 2);
        assert_eq!(failed.note, "still no fit", "note follows the latest emission");
        assert!(failed.last_seen_s >= failed.first_seen_s);
        assert_eq!(
            metrics.counter_value_with("kube.events.emitted", &[("reason", "FailedScheduling")]),
            1
        );
        assert_eq!(
            metrics.counter_value_with("kube.events.coalesced", &[("reason", "FailedScheduling")]),
            1
        );
    }

    #[test]
    fn events_carry_the_regarding_trace() {
        let api = client();
        let rec = EventRecorder::new("tester", Metrics::new());
        let mut pod = PodView::build("p2", "i.sif", Resources::ZERO, &[]);
        pod.meta.set_annotation(TRACE_ANNOTATION, "00000000deadbeef-0000000000000001");
        let pod = api.create(pod).unwrap();
        rec.event(&api, &pod, EVENT_NORMAL, "Started", "running").unwrap();

        let events = api.list(KIND_EVENT, &ListOptions::all()).unwrap().items;
        let v = EventView::from_object(&events[0]).unwrap();
        assert_eq!(
            v.trace.as_deref(),
            pod.meta.annotation(TRACE_ANNOTATION),
            "event carries the pod's trace annotation verbatim"
        );
        assert_eq!(v.trace_id(), Some("00000000deadbeef"));
    }

    #[test]
    fn zero_window_never_coalesces() {
        let api = client();
        let rec = EventRecorder::new("tester", Metrics::new()).with_window_s(0.0);
        let pod = api.create(PodView::build("p3", "i.sif", Resources::ZERO, &[])).unwrap();
        rec.event(&api, &pod, EVENT_NORMAL, "Started", "a").unwrap();
        rec.event(&api, &pod, EVENT_NORMAL, "Started", "b").unwrap();
        assert_eq!(api.list(KIND_EVENT, &ListOptions::all()).unwrap().items.len(), 2);
    }

    #[test]
    fn gc_reaps_expired_events() {
        let api = client();
        let metrics = Metrics::new();
        let rec = EventRecorder::new("tester", metrics.clone());
        let pod = api.create(PodView::build("p4", "i.sif", Resources::ZERO, &[])).unwrap();
        rec.event(&api, &pod, EVENT_NORMAL, "Started", "x").unwrap();
        // A generous TTL keeps it; a negative TTL expires everything.
        assert_eq!(gc_expired(&api, &metrics, 3600.0).unwrap(), 0);
        assert_eq!(gc_expired(&api, &metrics, -1.0).unwrap(), 1);
        assert!(api.list(KIND_EVENT, &ListOptions::all()).unwrap().items.is_empty());
        assert_eq!(metrics.counter_value("kube.events.gc"), 1);

        // A bump after GC recreates rather than erroring.
        rec.event(&api, &pod, EVENT_NORMAL, "Started", "y").unwrap();
        assert_eq!(api.list(KIND_EVENT, &ListOptions::all()).unwrap().items.len(), 1);
    }
}
