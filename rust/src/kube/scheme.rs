//! Group/Version/Kind scheme: the type registry of the API machinery.
//!
//! Kubernetes never hardcodes kinds — clients resolve user-facing aliases
//! (`po`, `pods`, `torquejobs`) through a scheme that maps every registered
//! kind to its [`GroupVersionKind`], plural, and short names. CRDs such as
//! the paper's `TorqueJob` (Fig. 3, `wlm.sylabs.io/v1alpha1`) register into
//! the same scheme the built-ins use, which is exactly what lets the
//! Torque-Operator "introduce a new object kind" without the CLI, the
//! store, or the transport learning anything new.

use super::api::{
    CrdView, KubeObject, APIEXTENSIONS_API_VERSION, KIND_CUSTOMRESOURCEDEFINITION,
    KIND_DEPLOYMENT, KIND_NODE, KIND_POD, KIND_PODDISRUPTIONBUDGET, KIND_SLURMJOB,
    KIND_TORQUEJOB, POLICY_API_VERSION, WLM_API_VERSION,
};
use crate::encoding::Value;
use crate::util::{Error, Result};
use std::sync::{Arc, OnceLock, RwLock};

/// The coordinates of an object kind in the API: `group/version, Kind`.
/// Built-ins live in the core (empty) group; CRDs carry their own group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupVersionKind {
    pub group: String,
    pub version: String,
    pub kind: String,
}

impl GroupVersionKind {
    /// A core-group kind (`apiVersion: v1`).
    pub fn core(version: impl Into<String>, kind: impl Into<String>) -> Self {
        GroupVersionKind { group: String::new(), version: version.into(), kind: kind.into() }
    }

    pub fn new(
        group: impl Into<String>,
        version: impl Into<String>,
        kind: impl Into<String>,
    ) -> Self {
        GroupVersionKind { group: group.into(), version: version.into(), kind: kind.into() }
    }

    /// The manifest `apiVersion` string: `group/version`, or bare `version`
    /// for the core group.
    pub fn api_version(&self) -> String {
        if self.group.is_empty() {
            self.version.clone()
        } else {
            format!("{}/{}", self.group, self.version)
        }
    }

    /// Parse an `apiVersion` + `kind` pair back into a GVK.
    pub fn from_api_version(api_version: &str, kind: impl Into<String>) -> Self {
        match api_version.split_once('/') {
            Some((g, v)) => GroupVersionKind::new(g, v, kind),
            None => GroupVersionKind::core(api_version, kind),
        }
    }
}

impl std::fmt::Display for GroupVersionKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}, Kind={}", self.api_version(), self.kind)
    }
}

/// One registered kind: its GVK plus the aliases `kubectl`-style tooling
/// accepts (plural and short names, matched case-insensitively).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KindSpec {
    pub gvk: GroupVersionKind,
    pub plural: String,
    pub short_names: Vec<String>,
}

impl KindSpec {
    pub fn new(gvk: GroupVersionKind, plural: impl Into<String>, short_names: &[&str]) -> Self {
        // Aliases are matched against lowercased queries, so store them
        // lowercased — otherwise an uppercase registration is unreachable.
        KindSpec {
            gvk,
            plural: plural.into().to_ascii_lowercase(),
            short_names: short_names.iter().map(|s| s.to_ascii_lowercase()).collect(),
        }
    }

    /// Does `alias` (already lowercased) name this kind?
    fn matches(&self, alias: &str) -> bool {
        self.gvk.kind.to_ascii_lowercase() == alias
            || self.plural == alias
            || self.short_names.iter().any(|s| s == alias)
    }
}

/// The kind registry. A `Scheme` is cheap to build and immutable once
/// shared; the process-wide default (built-ins + the paper's WLM CRDs) is
/// available through [`default_scheme`].
#[derive(Debug, Clone, Default)]
pub struct Scheme {
    kinds: Vec<KindSpec>,
}

impl Scheme {
    /// An empty scheme (register everything yourself).
    pub fn new() -> Scheme {
        Scheme::default()
    }

    /// The built-in kinds every cluster serves: Pod, Node, Deployment.
    pub fn with_builtins() -> Scheme {
        let mut s = Scheme::new();
        s.register(KindSpec::new(GroupVersionKind::core("v1", KIND_POD), "pods", &["po"]))
            .expect("builtin");
        s.register(KindSpec::new(GroupVersionKind::core("v1", KIND_NODE), "nodes", &["no"]))
            .expect("builtin");
        s.register(KindSpec::new(
            GroupVersionKind::core("v1", KIND_DEPLOYMENT),
            "deployments",
            &["deploy"],
        ))
        .expect("builtin");
        s
    }

    /// Register a kind; rejects duplicate kinds and colliding aliases.
    pub fn register(&mut self, spec: KindSpec) -> Result<()> {
        let mut aliases = vec![spec.gvk.kind.to_ascii_lowercase(), spec.plural.clone()];
        aliases.extend(spec.short_names.iter().cloned());
        for alias in &aliases {
            if self.resolve(alias).is_some() {
                return Err(Error::config(format!(
                    "scheme: alias `{alias}` already registered (while adding {})",
                    spec.gvk
                )));
            }
        }
        self.kinds.push(spec);
        Ok(())
    }

    /// Register a CRD kind under the paper's `wlm.sylabs.io/v1alpha1` group
    /// (Fig. 3). This is the one-liner an operator author calls.
    pub fn register_wlm_crd(
        &mut self,
        kind: &str,
        plural: &str,
        short_names: &[&str],
    ) -> Result<()> {
        self.register_grouped_crd(WLM_API_VERSION, kind, plural, short_names)
    }

    /// Register a CRD kind under the queue layer's `kueue.x-k8s.io`
    /// group (PR 2: ClusterQueue/LocalQueue and friends).
    pub fn register_kueue_crd(
        &mut self,
        kind: &str,
        plural: &str,
        short_names: &[&str],
    ) -> Result<()> {
        self.register_grouped_crd(crate::kueue::KUEUE_API_VERSION, kind, plural, short_names)
    }

    /// Register a kind under an arbitrary `group/version` apiVersion —
    /// the generic entry point the grouped wrappers above delegate to
    /// (and what new subsystems call directly, e.g. the autoscale layer's
    /// `autoscaling/v2` and `metrics.k8s.io/v1beta1` kinds).
    pub fn register_grouped_crd(
        &mut self,
        api_version: &str,
        kind: &str,
        plural: &str,
        short_names: &[&str],
    ) -> Result<()> {
        let (group, version) = api_version
            .split_once('/')
            .ok_or_else(|| Error::internal("CRD apiVersion must be group/version"))?;
        self.register(KindSpec::new(
            GroupVersionKind::new(group, version, kind),
            plural,
            short_names,
        ))
    }

    /// Resolve a user-facing alias (kind, plural, or short name; any case)
    /// to its registration.
    pub fn resolve(&self, alias: &str) -> Option<&KindSpec> {
        let lower = alias.to_ascii_lowercase();
        self.kinds.iter().find(|k| k.matches(&lower))
    }

    /// Canonical kind name for an alias (`po` → `Pod`). Unknown aliases
    /// resolve to `None`; CLI callers typically fall back to the raw string
    /// so unregistered CRD kinds still work end to end.
    pub fn canonical_kind(&self, alias: &str) -> Option<&str> {
        self.resolve(alias).map(|k| k.gvk.kind.as_str())
    }

    /// The `apiVersion` a registered kind is served under.
    pub fn api_version_for(&self, kind: &str) -> Option<String> {
        self.resolve(kind).map(|k| k.gvk.api_version())
    }

    /// Build a new object of a registered kind with the correct
    /// `apiVersion` stamped (accepts any alias).
    pub fn object(&self, alias: &str, name: &str, spec: Value) -> Result<KubeObject> {
        let reg = self
            .resolve(alias)
            .ok_or_else(|| Error::config(format!("scheme: unknown kind alias `{alias}`")))?;
        let mut o = KubeObject::new(reg.gvk.kind.clone(), name, spec);
        o.api_version = reg.gvk.api_version();
        Ok(o)
    }

    /// All registered kinds.
    pub fn kinds(&self) -> &[KindSpec] {
        &self.kinds
    }
}

/// The process-wide default scheme: built-ins plus the two WLM CRDs the
/// operators ship (TorqueJob, SlurmJob), the queue layer's admission CRDs
/// (ClusterQueue, LocalQueue), and the autoscale layer's kinds (the
/// `autoscaling/v2` HorizontalPodAutoscaler and the `metrics.k8s.io`
/// NodeMetrics/PodMetrics the kubelets publish). Controllers and the CLI
/// resolve against this unless handed a custom scheme.
pub fn default_scheme() -> &'static Scheme {
    static SCHEME: OnceLock<Scheme> = OnceLock::new();
    SCHEME.get_or_init(|| {
        let mut s = Scheme::with_builtins();
        s.register_wlm_crd(KIND_TORQUEJOB, "torquejobs", &["tj"]).expect("torquejob crd");
        s.register_wlm_crd(KIND_SLURMJOB, "slurmjobs", &["sj"]).expect("slurmjob crd");
        s.register_kueue_crd(crate::kueue::KIND_CLUSTERQUEUE, "clusterqueues", &["cq"])
            .expect("clusterqueue crd");
        s.register_kueue_crd(crate::kueue::KIND_LOCALQUEUE, "localqueues", &["lq"])
            .expect("localqueue crd");
        s.register_grouped_crd(
            crate::autoscale::AUTOSCALING_API_VERSION,
            crate::autoscale::KIND_HPA,
            "horizontalpodautoscalers",
            &["hpa"],
        )
        .expect("hpa crd");
        s.register_grouped_crd(
            crate::autoscale::METRICS_API_VERSION,
            crate::autoscale::KIND_NODEMETRICS,
            "nodemetrics",
            &[],
        )
        .expect("nodemetrics crd");
        s.register_grouped_crd(
            crate::autoscale::METRICS_API_VERSION,
            crate::autoscale::KIND_PODMETRICS,
            "podmetrics",
            &[],
        )
        .expect("podmetrics crd");
        s.register_grouped_crd(
            super::events::EVENTS_API_VERSION,
            super::events::KIND_EVENT,
            "events",
            &["ev"],
        )
        .expect("event kind");
        s.register_grouped_crd(
            POLICY_API_VERSION,
            KIND_PODDISRUPTIONBUDGET,
            "poddisruptionbudgets",
            &["pdb"],
        )
        .expect("pdb kind");
        s.register_grouped_crd(
            APIEXTENSIONS_API_VERSION,
            KIND_CUSTOMRESOURCEDEFINITION,
            "customresourcedefinitions",
            &["crd", "crds"],
        )
        .expect("crd kind");
        s
    })
}

/// A *runtime-extensible* scheme: the server-owned registry behind
/// CustomResourceDefinition serving. Seeded from [`default_scheme`], it can
/// grow while the server runs — creating/applying a CRD object calls
/// [`SchemeRegistry::register_crd`], after which the new kind resolves for
/// every client of that server exactly like a built-in. Cloning shares the
/// underlying registry (the server and all its services see one scheme).
#[derive(Debug, Clone)]
pub struct SchemeRegistry {
    inner: Arc<RwLock<Scheme>>,
}

impl Default for SchemeRegistry {
    fn default() -> Self {
        SchemeRegistry::with_defaults()
    }
}

impl SchemeRegistry {
    /// A registry seeded with every [`default_scheme`] kind.
    pub fn with_defaults() -> SchemeRegistry {
        SchemeRegistry { inner: Arc::new(RwLock::new(default_scheme().clone())) }
    }

    /// Register the kind a CustomResourceDefinition describes. Idempotent
    /// for an identical re-registration (apply of the same CRD); a
    /// *conflicting* registration (same alias, different GVK) is rejected.
    pub fn register_crd(&self, crd: &CrdView) -> Result<()> {
        let spec = KindSpec::new(
            GroupVersionKind::new(crd.group.clone(), crd.version.clone(), crd.kind.clone()),
            crd.plural.clone(),
            &crd.short_names.iter().map(String::as_str).collect::<Vec<_>>(),
        );
        let mut s = self.inner.write().unwrap_or_else(|e| e.into_inner());
        if let Some(existing) = s.resolve(&crd.kind) {
            if *existing == spec {
                return Ok(());
            }
        }
        s.register(spec)
    }

    /// Canonical kind for an alias (owned — the lock is released on return).
    pub fn canonical_kind(&self, alias: &str) -> Option<String> {
        let s = self.inner.read().unwrap_or_else(|e| e.into_inner());
        s.canonical_kind(alias).map(String::from)
    }

    /// The apiVersion a kind is served under.
    pub fn api_version_for(&self, kind: &str) -> Option<String> {
        let s = self.inner.read().unwrap_or_else(|e| e.into_inner());
        s.api_version_for(kind)
    }

    /// The GVK metric-label value for a kind: the registered plural
    /// (`Pod` → `pods`), or the lowercased kind for unregistered CRDs —
    /// labels stay low-cardinality either way.
    pub fn gvk_label(&self, kind: &str) -> String {
        let s = self.inner.read().unwrap_or_else(|e| e.into_inner());
        s.resolve(kind)
            .map(|k| k.plural.clone())
            .unwrap_or_else(|| kind.to_ascii_lowercase())
    }

    /// A point-in-time copy of the registry (for enumeration/tests).
    pub fn snapshot(&self) -> Scheme {
        self.inner.read().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gvk_api_version_roundtrip() {
        let core = GroupVersionKind::core("v1", "Pod");
        assert_eq!(core.api_version(), "v1");
        let crd = GroupVersionKind::new("wlm.sylabs.io", "v1alpha1", "TorqueJob");
        assert_eq!(crd.api_version(), "wlm.sylabs.io/v1alpha1");
        assert_eq!(
            GroupVersionKind::from_api_version("wlm.sylabs.io/v1alpha1", "TorqueJob"),
            crd
        );
        assert_eq!(GroupVersionKind::from_api_version("v1", "Pod"), core);
        assert_eq!(crd.to_string(), "wlm.sylabs.io/v1alpha1, Kind=TorqueJob");
    }

    #[test]
    fn default_scheme_resolves_all_cli_aliases() {
        let s = default_scheme();
        for (alias, kind) in [
            ("pod", "Pod"),
            ("pods", "Pod"),
            ("po", "Pod"),
            ("Pod", "Pod"),
            ("node", "Node"),
            ("nodes", "Node"),
            ("no", "Node"),
            ("deployment", "Deployment"),
            ("deployments", "Deployment"),
            ("deploy", "Deployment"),
            ("torquejob", "TorqueJob"),
            ("torquejobs", "TorqueJob"),
            ("tj", "TorqueJob"),
            ("slurmjob", "SlurmJob"),
            ("slurmjobs", "SlurmJob"),
            ("sj", "SlurmJob"),
            ("clusterqueue", "ClusterQueue"),
            ("clusterqueues", "ClusterQueue"),
            ("cq", "ClusterQueue"),
            ("localqueue", "LocalQueue"),
            ("localqueues", "LocalQueue"),
            ("lq", "LocalQueue"),
            ("hpa", "HorizontalPodAutoscaler"),
            ("horizontalpodautoscalers", "HorizontalPodAutoscaler"),
            ("nodemetrics", "NodeMetrics"),
            ("podmetrics", "PodMetrics"),
            ("event", "Event"),
            ("events", "Event"),
            ("ev", "Event"),
            ("poddisruptionbudget", "PodDisruptionBudget"),
            ("poddisruptionbudgets", "PodDisruptionBudget"),
            ("pdb", "PodDisruptionBudget"),
            ("customresourcedefinition", "CustomResourceDefinition"),
            ("customresourcedefinitions", "CustomResourceDefinition"),
            ("crd", "CustomResourceDefinition"),
            ("crds", "CustomResourceDefinition"),
        ] {
            assert_eq!(s.canonical_kind(alias), Some(kind), "alias {alias}");
        }
        assert_eq!(s.canonical_kind("gizmo"), None);
        assert_eq!(
            s.api_version_for("cq").as_deref(),
            Some(crate::kueue::KUEUE_API_VERSION)
        );
        assert_eq!(
            s.api_version_for("hpa").as_deref(),
            Some(crate::autoscale::AUTOSCALING_API_VERSION)
        );
        assert_eq!(
            s.api_version_for("podmetrics").as_deref(),
            Some(crate::autoscale::METRICS_API_VERSION)
        );
        assert_eq!(
            s.api_version_for("ev").as_deref(),
            Some(crate::kube::events::EVENTS_API_VERSION)
        );
        assert_eq!(s.api_version_for("pdb").as_deref(), Some(POLICY_API_VERSION));
        assert_eq!(
            s.api_version_for("crd").as_deref(),
            Some(APIEXTENSIONS_API_VERSION)
        );
    }

    #[test]
    fn registry_extends_at_runtime() {
        let reg = SchemeRegistry::with_defaults();
        assert_eq!(reg.canonical_kind("po").as_deref(), Some("Pod"));
        assert_eq!(reg.canonical_kind("fj"), None);
        let crd = CrdView::from_object(&CrdView::build(
            "stable.example.com",
            "v1",
            "FlinkJob",
            "flinkjobs",
            &["fj"],
        ))
        .unwrap();
        reg.register_crd(&crd).unwrap();
        assert_eq!(reg.canonical_kind("fj").as_deref(), Some("FlinkJob"));
        assert_eq!(reg.api_version_for("FlinkJob").as_deref(), Some("stable.example.com/v1"));
        assert_eq!(reg.gvk_label("FlinkJob"), "flinkjobs");
        assert_eq!(reg.gvk_label("Gizmo"), "gizmo");
        // Re-registering the identical CRD is an idempotent no-op...
        reg.register_crd(&crd).unwrap();
        // ...but a conflicting registration (same alias, new group) is not.
        let clash = CrdView::from_object(&CrdView::build(
            "other.example.com",
            "v1",
            "FlinkJob",
            "flinkjobs",
            &["fj"],
        ))
        .unwrap();
        assert!(reg.register_crd(&clash).is_err());
        // The process-static default scheme is untouched.
        assert_eq!(default_scheme().canonical_kind("fj"), None);
    }

    #[test]
    fn crd_registration_and_object_builder() {
        let mut s = Scheme::with_builtins();
        s.register_wlm_crd("TorqueJob", "torquejobs", &["tj"]).unwrap();
        assert_eq!(
            s.api_version_for("tj").as_deref(),
            Some("wlm.sylabs.io/v1alpha1")
        );
        let o = s.object("tj", "cow", Value::map().with("batch", "echo x")).unwrap();
        assert_eq!(o.kind, "TorqueJob");
        assert_eq!(o.api_version, WLM_API_VERSION);
        let p = s.object("pods", "p1", Value::map()).unwrap();
        assert_eq!(p.kind, "Pod");
        assert_eq!(p.api_version, "v1");
        assert!(s.object("gizmo", "g", Value::map()).is_err());
    }

    #[test]
    fn mixed_case_registrations_resolve() {
        let mut s = Scheme::new();
        s.register_wlm_crd("FlinkJob", "FlinkJobs", &["FJ"]).unwrap();
        for alias in ["flinkjob", "FlinkJob", "flinkjobs", "FlinkJobs", "fj", "FJ"] {
            assert_eq!(s.canonical_kind(alias), Some("FlinkJob"), "alias {alias}");
        }
    }

    #[test]
    fn duplicate_aliases_rejected() {
        let mut s = Scheme::with_builtins();
        // Kind collides.
        assert!(s
            .register(KindSpec::new(GroupVersionKind::core("v1", "Pod"), "pods2", &[]))
            .is_err());
        // Short name collides with an existing alias.
        assert!(s
            .register(KindSpec::new(GroupVersionKind::core("v1", "Podling"), "podlings", &["po"]))
            .is_err());
        // Clean registration is fine.
        assert!(s
            .register(KindSpec::new(GroupVersionKind::core("v1", "Widget"), "widgets", &["wi"]))
            .is_ok());
        assert_eq!(s.canonical_kind("wi"), Some("Widget"));
    }
}
