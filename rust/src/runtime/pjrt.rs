//! PJRT execution host: a dedicated thread owning the (non-Send) PJRT CPU
//! client, compiled-executable cache, and the run loops for compute
//! payloads. Other threads talk to it through [`PjrtHandle`], which
//! implements [`ComputeEngine`] for the container runtime.
//!
//! Flow per compute payload (`cropyield_train_small`, 200 steps):
//!   1. run the artifact's `init` HLO once with the job seed → params
//!   2. loop: execute the step HLO with (step, params…) → (params…, metric)
//!   3. stream (step, metric) back to the caller; honour cancellation
//!
//! Artifacts are HLO TEXT compiled once per process and cached (compile is
//! the expensive part; execution reuses the loaded executable).

use super::manifest::{ArtifactEntry, Manifest};
use crate::cluster::Metrics;
use crate::rt::{self, Shutdown};
use crate::singularity::{ComputeEngine, ComputeSummary};
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// A compute request sent to the PJRT thread.
struct Request {
    artifact: String,
    steps: u32,
    seed: u64,
    /// Per-step metric stream back to the caller.
    step_tx: Sender<(u32, f32)>,
    cancel: Shutdown,
    done_tx: Sender<Result<ComputeSummary>>,
}

/// Cloneable handle; implements [`ComputeEngine`].
#[derive(Clone)]
pub struct PjrtHandle {
    tx: Sender<Request>,
    metrics: Metrics,
    manifest: Arc<Manifest>,
}

/// Spawn the PJRT host thread over an artifacts directory.
pub fn start_pjrt_host(
    artifacts_dir: impl AsRef<Path>,
    metrics: Metrics,
    shutdown: Shutdown,
) -> Result<PjrtHandle> {
    let manifest = Arc::new(Manifest::load(artifacts_dir)?);
    let (tx, rx) = channel::<Request>();
    let m2 = manifest.clone();
    let met2 = metrics.clone();
    let (boot_tx, boot_rx) = channel::<Result<()>>();
    rt::spawn_named("pjrt-host", move || host_loop(m2, rx, met2, shutdown, boot_tx));
    // Surface client-construction errors synchronously.
    boot_rx
        .recv()
        .map_err(|_| Error::compute("pjrt host thread died during boot"))??;
    Ok(PjrtHandle { tx, metrics, manifest })
}

impl PjrtHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }
}

impl ComputeEngine for PjrtHandle {
    fn run(
        &self,
        artifact: &str,
        steps: u32,
        seed: u64,
        on_step: &mut dyn FnMut(u32, f32) -> bool,
    ) -> Result<ComputeSummary> {
        let (step_tx, step_rx) = channel();
        let (done_tx, done_rx) = channel();
        let cancel = Shutdown::new();
        self.tx
            .send(Request {
                artifact: artifact.to_string(),
                steps,
                seed,
                step_tx,
                cancel: cancel.clone(),
                done_tx,
            })
            .map_err(|_| Error::compute("pjrt host gone"))?;
        // Pump per-step events until the host reports completion.
        loop {
            // Drain step events (non-blocking) and forward to the caller.
            while let Ok((step, metric)) = step_rx.try_recv() {
                if !on_step(step, metric) {
                    cancel.trigger();
                }
            }
            match done_rx.recv_timeout(std::time::Duration::from_micros(500)) {
                Ok(result) => {
                    // Flush any remaining step events for accurate logs.
                    while let Ok((step, metric)) = step_rx.try_recv() {
                        if !on_step(step, metric) {
                            cancel.trigger();
                        }
                    }
                    self.metrics.inc("pjrt.runs");
                    return result;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(_) => return Err(Error::compute("pjrt host dropped request")),
            }
        }
    }
}

// ------------------------------------------------------- host thread body

fn host_loop(
    manifest: Arc<Manifest>,
    rx: Receiver<Request>,
    metrics: Metrics,
    shutdown: Shutdown,
    boot_tx: Sender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = boot_tx.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = boot_tx.send(Err(Error::compute(format!("PjRtClient::cpu: {e}"))));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    loop {
        let req = match rx.recv_timeout(std::time::Duration::from_millis(50)) {
            Ok(r) => r,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if shutdown.is_triggered() {
                    return;
                }
                continue;
            }
            Err(_) => return,
        };
        let result = serve_request(&client, &manifest, &mut cache, &metrics, &req);
        let _ = req.done_tx.send(result);
    }
}

fn compile<'a>(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &'a mut HashMap<String, xla::PjRtLoadedExecutable>,
    metrics: &Metrics,
    name: &str,
) -> Result<&'a xla::PjRtLoadedExecutable> {
    if !cache.contains_key(name) {
        let entry = manifest.get(name)?;
        let path = manifest.hlo_path(entry);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::compute("non-utf8 path"))?,
        )
        .map_err(|e| Error::compute(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::compute(format!("compile {name}: {e}")))?;
        metrics.observe("pjrt.compile_ns", t0.elapsed().as_nanos() as u64);
        metrics.inc("pjrt.compiles");
        cache.insert(name.to_string(), exe);
    }
    Ok(cache.get(name).unwrap())
}

/// Execute a compiled artifact; unpacks the returned 1-element tuple into
/// its constituent literals.
fn execute(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
    metrics: &Metrics,
) -> Result<Vec<xla::Literal>> {
    let t0 = std::time::Instant::now();
    let result = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| Error::compute(format!("execute: {e}")))?;
    let out = result
        .first()
        .and_then(|d| d.first())
        .ok_or_else(|| Error::compute("empty execution result"))?
        .to_literal_sync()
        .map_err(|e| Error::compute(format!("to_literal: {e}")))?;
    metrics.observe("pjrt.execute_ns", t0.elapsed().as_nanos() as u64);
    // aot.py lowers with return_tuple=True: always a tuple, even for 1.
    out.to_tuple().map_err(|e| Error::compute(format!("untuple: {e}")))
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.to_vec::<f32>()
        .map_err(|e| Error::compute(format!("metric read: {e}")))?
        .first()
        .copied()
        .ok_or_else(|| Error::compute("empty metric literal"))
}

fn serve_request(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    metrics: &Metrics,
    req: &Request,
) -> Result<ComputeSummary> {
    let entry: ArtifactEntry = manifest.get(&req.artifact)?.clone();
    match entry.role.as_str() {
        "train_step" | "infer" => {
            let init_name = entry
                .init
                .as_ref()
                .ok_or_else(|| Error::compute("step artifact without init"))?
                .clone();
            // 1) init(seed) -> params
            let params = {
                let init_exe = compile(client, manifest, cache, metrics, &init_name)?;
                let seed = xla::Literal::scalar(req.seed as i32);
                execute(init_exe, &[seed], metrics)?
            };
            let param_count = entry.param_count.unwrap_or(params.len());
            let metric_idx = entry.metric_output_index.unwrap_or(param_count);
            let metric_name =
                entry.metric.clone().unwrap_or_else(|| "metric".to_string());
            if params.len() != param_count {
                return Err(Error::compute(format!(
                    "init produced {} arrays, manifest says {param_count}",
                    params.len()
                )));
            }
            // 2) step loop
            let exe = compile(client, manifest, cache, metrics, &req.artifact)?;
            let mut params = params;
            let mut first_metric = f32::NAN;
            let mut last_metric = f32::NAN;
            let mut done = 0u32;
            for step in 0..req.steps {
                if req.cancel.is_triggered() {
                    break;
                }
                let mut inputs = Vec::with_capacity(params.len() + 1);
                inputs.push(xla::Literal::scalar(step as i32));
                inputs.append(&mut params);
                let mut outputs = execute(exe, &inputs, metrics)?;
                let metric = scalar_f32(&outputs[metric_idx])?;
                if entry.role == "train_step" {
                    // params carried forward: outputs[..param_count]
                    params = outputs.drain(..param_count).collect();
                } else {
                    // infer: params unchanged; reuse the inputs we moved out.
                    params = inputs.drain(1..).collect();
                }
                if step == 0 {
                    first_metric = metric;
                }
                last_metric = metric;
                done = step + 1;
                let _ = req.step_tx.send((step, metric));
            }
            metrics.add("pjrt.steps", done as u64);
            Ok(ComputeSummary {
                steps_done: done,
                first_metric,
                last_metric,
                metric_name,
            })
        }
        "init" => {
            let exe = compile(client, manifest, cache, metrics, &req.artifact)?;
            let seed = xla::Literal::scalar(req.seed as i32);
            let out = execute(exe, &[seed], metrics)?;
            Ok(ComputeSummary {
                steps_done: 1,
                first_metric: out.len() as f32,
                last_metric: out.len() as f32,
                metric_name: "arrays".into(),
            })
        }
        other => Err(Error::compute(format!("unknown artifact role `{other}`"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn train_loss_decreases_via_pjrt() {
        let Some(dir) = artifacts_dir() else { return };
        let sd = Shutdown::new();
        let handle = start_pjrt_host(&dir, Metrics::new(), sd.clone()).unwrap();
        let mut series = Vec::new();
        let summary = handle
            .run("cropyield_train_tiny", 30, 0, &mut |step, loss| {
                series.push((step, loss));
                true
            })
            .unwrap();
        assert_eq!(summary.steps_done, 30);
        assert_eq!(summary.metric_name, "loss");
        assert_eq!(series.len(), 30);
        assert!(
            summary.last_metric < summary.first_metric * 0.8,
            "loss {} -> {} did not decrease",
            summary.first_metric,
            summary.last_metric
        );
        sd.trigger();
    }

    #[test]
    fn infer_runs_and_cancels() {
        let Some(dir) = artifacts_dir() else { return };
        let sd = Shutdown::new();
        let handle = start_pjrt_host(&dir, Metrics::new(), sd.clone()).unwrap();
        let summary = handle
            .run("cropyield_infer_tiny", 5, 1, &mut |_, m| {
                assert!(m.is_finite());
                true
            })
            .unwrap();
        assert_eq!(summary.steps_done, 5);
        assert_eq!(summary.metric_name, "mse");
        // Cancellation after 3 steps.
        let summary = handle
            .run("cropyield_train_tiny", 100, 0, &mut |step, _| step < 2)
            .unwrap();
        assert!(summary.steps_done < 100, "cancelled early: {}", summary.steps_done);
        sd.trigger();
    }

    #[test]
    fn deterministic_across_runs_same_seed() {
        let Some(dir) = artifacts_dir() else { return };
        let sd = Shutdown::new();
        let handle = start_pjrt_host(&dir, Metrics::new(), sd.clone()).unwrap();
        let run = |seed| {
            handle
                .run("cropyield_train_tiny", 5, seed, &mut |_, _| true)
                .unwrap()
                .last_metric
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4), "different seed, different init");
        sd.trigger();
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else { return };
        let sd = Shutdown::new();
        let handle = start_pjrt_host(&dir, Metrics::new(), sd.clone()).unwrap();
        assert!(handle.run("nope", 1, 0, &mut |_, _| true).is_err());
        sd.trigger();
    }
}
