//! Singularity-CRI: the Container Runtime Interface shim that lets the
//! Kubernetes kubelet drive Singularity containers (paper §III: "Kubernetes
//! supports Docker by default, though it can be adjusted to perform
//! services for Singularity by adding Singularity-CRI").
//!
//! The interface is a distilled CRI: start / status / stop / remove, with
//! container state held by the shim (as the real CRI daemon does).

use super::runtime::{CancelToken, RunRequest, RunResult, Runtime};
use crate::cluster::SharedFs;
use crate::rt;
use crate::util::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// What the kubelet asks the CRI to run (one container of a pod).
#[derive(Debug, Clone)]
pub struct ContainerSpec {
    pub name: String,
    pub image: String,
    pub env: Vec<(String, String)>,
    pub seed: u64,
    pub time_scale: f64,
}

impl ContainerSpec {
    pub fn new(name: impl Into<String>, image: impl Into<String>) -> Self {
        ContainerSpec {
            name: name.into(),
            image: image.into(),
            env: Vec::new(),
            seed: 0,
            time_scale: 1.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ContainerId(pub u64);

#[derive(Debug, Clone, PartialEq)]
pub enum ContainerStatus {
    Running,
    Exited(RunResult),
    /// Start failed before the payload ran (image pull error etc.).
    Failed(String),
}

/// The distilled Container Runtime Interface.
pub trait Cri: Send + Sync {
    /// Runtime name as reported in node status (`singularity`, `docker-sim`).
    fn runtime_name(&self) -> String;
    /// Start a container; returns immediately with an id.
    fn start(&self, spec: ContainerSpec, fs: SharedFs) -> Result<ContainerId>;
    fn status(&self, id: ContainerId) -> Result<ContainerStatus>;
    /// Request termination (idempotent). Does not wait.
    fn stop(&self, id: ContainerId) -> Result<()>;
    /// Forget a terminal container. Errors if still running.
    fn remove(&self, id: ContainerId) -> Result<()>;
    /// Block until the container exits (test/bench convenience).
    fn wait(&self, id: ContainerId, timeout: std::time::Duration) -> Result<RunResult> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            match self.status(id)? {
                ContainerStatus::Exited(r) => return Ok(r),
                ContainerStatus::Failed(e) => return Err(Error::container(e)),
                ContainerStatus::Running => {
                    if std::time::Instant::now() >= deadline {
                        return Err(Error::container("wait timeout"));
                    }
                    std::thread::sleep(std::time::Duration::from_micros(300));
                }
            }
        }
    }
}

struct Entry {
    cancel: CancelToken,
    state: ContainerStatus,
}

/// CRI shim running containers on a [`Runtime`] via one thread each.
pub struct SingularityCri {
    runtime: Runtime,
    containers: Arc<Mutex<HashMap<u64, Entry>>>,
    next_id: AtomicU64,
}

impl SingularityCri {
    pub fn new(runtime: Runtime) -> Arc<Self> {
        Arc::new(SingularityCri {
            runtime,
            containers: Arc::new(Mutex::new(HashMap::new())),
            next_id: AtomicU64::new(1),
        })
    }
}

impl Cri for Arc<SingularityCri> {
    fn runtime_name(&self) -> String {
        format!("{}-cri", self.runtime.kind.as_str())
    }

    fn start(&self, spec: ContainerSpec, fs: SharedFs) -> Result<ContainerId> {
        // Fail fast on unknown images (CRI ImageService would).
        if !self.runtime.registry().exists(&spec.image) {
            return Err(Error::container(format!("image not found: {}", spec.image)));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let cancel = CancelToken::new();
        self.containers
            .lock()
            .unwrap()
            .insert(id, Entry { cancel: cancel.clone(), state: ContainerStatus::Running });
        let containers = self.containers.clone();
        let runtime = self.runtime.clone();
        rt::spawn_named(&format!("cri-{}", spec.name), move || {
            let mut req = RunRequest::new(spec.image.clone());
            req.env = spec.env.clone();
            req.seed = spec.seed;
            req.time_scale = spec.time_scale;
            let state = match runtime.run(&req, &fs, &cancel) {
                Ok(res) => ContainerStatus::Exited(res),
                Err(e) => ContainerStatus::Failed(e.to_string()),
            };
            if let Some(entry) = containers.lock().unwrap().get_mut(&id) {
                entry.state = state;
            }
        });
        Ok(ContainerId(id))
    }

    fn status(&self, id: ContainerId) -> Result<ContainerStatus> {
        self.containers
            .lock()
            .unwrap()
            .get(&id.0)
            .map(|e| e.state.clone())
            .ok_or_else(|| Error::container(format!("no such container {}", id.0)))
    }

    fn stop(&self, id: ContainerId) -> Result<()> {
        match self.containers.lock().unwrap().get(&id.0) {
            Some(entry) => {
                entry.cancel.trigger();
                Ok(())
            }
            None => Err(Error::container(format!("no such container {}", id.0))),
        }
    }

    fn remove(&self, id: ContainerId) -> Result<()> {
        let mut map = self.containers.lock().unwrap();
        match map.get(&id.0) {
            Some(e) if matches!(e.state, ContainerStatus::Running) => {
                Err(Error::container("container still running"))
            }
            Some(_) => {
                map.remove(&id.0);
                Ok(())
            }
            None => Err(Error::container(format!("no such container {}", id.0))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Metrics;
    use crate::singularity::image::{Payload, SifImage};
    use crate::singularity::registry::ImageRegistry;
    use crate::singularity::runtime::RuntimeKind;
    use std::time::Duration;

    fn cri() -> Arc<SingularityCri> {
        let reg = ImageRegistry::with_defaults();
        reg.push(SifImage::new("long.sif", Payload::Sleep { millis: 60_000 }));
        let rt = Runtime::new(RuntimeKind::Singularity, reg, Metrics::new());
        SingularityCri::new(rt)
    }

    #[test]
    fn start_wait_remove() {
        let cri = cri();
        let fs = SharedFs::new();
        let id = cri.start(ContainerSpec::new("c1", "lolcow_latest.sif"), fs).unwrap();
        let res = cri.wait(id, Duration::from_secs(5)).unwrap();
        assert!(res.success());
        assert!(res.stdout.contains("Moo"));
        cri.remove(id).unwrap();
        assert!(cri.status(id).is_err());
    }

    #[test]
    fn unknown_image_fails_fast() {
        let cri = cri();
        assert!(cri.start(ContainerSpec::new("c", "ghost.sif"), SharedFs::new()).is_err());
    }

    #[test]
    fn stop_kills_running_container() {
        let cri = cri();
        let id = cri.start(ContainerSpec::new("c", "long.sif"), SharedFs::new()).unwrap();
        assert_eq!(cri.status(id).unwrap(), ContainerStatus::Running);
        assert!(cri.remove(id).is_err(), "cannot remove running container");
        cri.stop(id).unwrap();
        let res = cri.wait(id, Duration::from_secs(5)).unwrap();
        assert!(res.cancelled);
        cri.remove(id).unwrap();
    }

    #[test]
    fn runtime_name_reflects_kind() {
        assert_eq!(cri().runtime_name(), "singularity-cri");
    }

    #[test]
    fn parallel_containers() {
        let cri = cri();
        let fs = SharedFs::new();
        let ids: Vec<_> = (0..16)
            .map(|i| {
                cri.start(ContainerSpec::new(format!("c{i}"), "lolcow_latest.sif"), fs.clone())
                    .unwrap()
            })
            .collect();
        for id in ids {
            assert!(cri.wait(id, Duration::from_secs(10)).unwrap().success());
        }
    }
}
